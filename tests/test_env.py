"""repro.env: the centralized XLA/JAX measurement-environment knobs."""
import os
import warnings

import pytest

from repro import env

_KEYS = ("JAX_PLATFORMS", "XLA_FLAGS", "JAX_ENABLE_X64")


@pytest.fixture(autouse=True)
def _restore_environment():
    import jax
    saved = {k: os.environ.get(k) for k in _KEYS}
    saved_x64 = bool(jax.config.read("jax_enable_x64"))
    yield
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    jax.config.update("jax_enable_x64", saved_x64)


def _force_jax_init():
    import jax
    jax.devices()


class TestKnobs:
    def test_host_device_count_merges_into_existing_flags(self):
        _force_jax_init()
        os.environ["XLA_FLAGS"] = \
            "--foo=1 --xla_force_host_platform_device_count=4"
        with pytest.warns(RuntimeWarning, match="after jax initialized"):
            env.set_host_device_count(8)
        flags = os.environ["XLA_FLAGS"]
        assert "--foo=1" in flags                      # preserved
        assert "--xla_force_host_platform_device_count=8" in flags
        assert "device_count=4" not in flags           # replaced, not stacked

    def test_set_platform_sets_env_and_warns_when_late(self):
        _force_jax_init()
        with pytest.warns(RuntimeWarning, match="not take effect"):
            env.set_platform("cpu")
        assert os.environ["JAX_PLATFORMS"] == "cpu"

    def test_enable_x64_toggles_live_jax_config(self):
        import jax
        env.enable_x64(True)
        assert os.environ["JAX_ENABLE_X64"] == "1"
        assert jax.config.read("jax_enable_x64") is True
        env.enable_x64(False)
        assert os.environ["JAX_ENABLE_X64"] == "0"
        assert jax.config.read("jax_enable_x64") is False

    def test_jax_initialized_detection(self):
        _force_jax_init()
        assert env._jax_initialized() is True


class TestBenchmarkPinning:
    def test_configure_applies_all_knobs(self):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            env.configure(platform="cpu", x64=False, host_devices=2)
        assert os.environ["JAX_PLATFORMS"] == "cpu"
        assert os.environ["JAX_ENABLE_X64"] == "0"
        assert "--xla_force_host_platform_device_count=2" in \
            os.environ["XLA_FLAGS"]

    def test_pin_for_benchmarks_pins_and_describes(self):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            d = env.pin_for_benchmarks()
        assert os.environ["JAX_PLATFORMS"] == "cpu"
        assert d["x64"] is False
        assert d["jax_platform"] == "cpu"
        assert d["device_count"] >= 1
        assert d["jax_version"]

    def test_pin_keeps_caller_exported_platform(self):
        os.environ["JAX_PLATFORMS"] = "cpu"
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            d = env.pin_for_benchmarks()
        assert os.environ["JAX_PLATFORMS"] == "cpu"
        assert "xla_flags" in d

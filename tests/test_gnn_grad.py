"""Gradient parity across kernel backends, for every zoo architecture.

`jax.grad` of the masked-CE training loss through a compiled Executable
must agree whether the forward ran on the ``pallas`` kernels (backward =
oracle-derived custom_vjp), the vectorized ``jax`` lowering, or the
``reference`` oracles — on generic random graphs AND the degenerate
topologies training actually hits: zero-in-degree nodes (nothing to
aggregate) and self-loop-only graphs (every node its own neighborhood).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import runtime
from repro.gnn.models import ARCHS, ZooSpec
from repro.runtime.fit import masked_cross_entropy

N = 18
F, HID = 6, 8
CLASSES = 3
BACKENDS = ("reference", "jax", "pallas")
GRAPH_KINDS = ("random", "zero_in_degree", "self_loops_only")


def _graph(kind: str) -> np.ndarray:
    rng = np.random.default_rng(11)
    if kind == "random":
        return rng.integers(0, N, (40, 2)).astype(np.int64)
    if kind == "zero_in_degree":
        # every edge lands in the first half: nodes N//2.. have in-degree 0
        src = rng.integers(0, N, 30)
        dst = rng.integers(0, N // 2, 30)
        return np.stack([src, dst], axis=1).astype(np.int64)
    if kind == "self_loops_only":
        return np.stack([np.arange(N)] * 2, axis=1).astype(np.int64)
    raise ValueError(kind)


def _grads(arch: str, kind: str, backend: str, params: dict | None):
    rng = np.random.default_rng(3)
    feats = rng.standard_normal((N, F)).astype(np.float32)
    labels = jnp.asarray(rng.integers(0, CLASSES, N).astype(np.int32))
    mask = jnp.asarray(rng.random(N) < 0.7)
    spec = ZooSpec(arch, F, HID, CLASSES, num_layers=2)
    exe = runtime.compile(spec, (_graph(kind), N, feats), backend=backend,
                          params=params, max_shard_n=16)

    def loss(p):
        return masked_cross_entropy(exe.forward(p), labels, mask)

    return exe.params, jax.grad(loss)(exe.params)


@settings(deadline=None, max_examples=15)
@given(arch=st.sampled_from(ARCHS), kind=st.sampled_from(GRAPH_KINDS))
def test_grad_parity_across_backends(arch, kind):
    params, g_ref = _grads(arch, kind, "reference", None)
    leaves_ref = jax.tree.leaves(g_ref)
    # degenerate graphs must still give finite gradients with signal
    assert all(bool(jnp.isfinite(l).all()) for l in leaves_ref)
    assert sum(float(jnp.sum(jnp.abs(l))) for l in leaves_ref) > 0
    for backend in BACKENDS[1:]:
        _, g = _grads(arch, kind, backend, params)
        for a, b in zip(leaves_ref, jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_training_step_moves_params_every_arch(arch):
    """One fit step on every architecture: loss finite, params move."""
    from repro.graphs.datasets import make_dataset

    ds = make_dataset("cora", seed=0, scale=0.1)
    spec = ZooSpec(arch, ds.profile.feature_dim, HID,
                   ds.profile.num_classes)
    res = runtime.fit(spec, ds, steps=2, backend="reference",
                      log=lambda s: None)
    assert np.isfinite(res.history[-1][1])
    before = runtime.compile(spec, ds, backend="reference").params
    moved = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(before)))
    assert moved > 0

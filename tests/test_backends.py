"""Backend parity: every op in the kernel registry must produce the same
numbers on `pallas`, `jax` and `reference` over hypothesis-generated shard
grids (extending the test_gnn_models oracle pattern one level down: the
reference backend IS the oracle, the others must match it allclose)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import registry

RNG = np.random.default_rng(42)
TOL = dict(atol=1e-4, rtol=1e-4)


def _others():
    return [registry.get_backend(n) for n in registry.list_backends()
            if n != "reference"]


def _check(op_name, make_args, **kw):
    ref = registry.get_backend("reference")
    ref_out = getattr(ref, op_name)(*make_args(), **kw)
    for be in _others():
        out = getattr(be, op_name)(*make_args(), **kw)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref_out),
            err_msg=f"{op_name}: backend {be.name} diverges from reference",
            **TOL)


class TestRegistryParity:
    def test_all_backends_registered(self):
        assert set(registry.list_backends()) >= {"pallas", "jax", "reference"}
        for name in registry.list_backends():
            be = registry.get_backend(name)
            for op in registry.OP_NAMES:
                assert callable(getattr(be, op)), (name, op)

    @settings(deadline=None, max_examples=8)
    @given(m=st.sampled_from([3, 16, 64]), k=st.sampled_from([8, 33]),
           n=st.sampled_from([4, 24]),
           act=st.sampled_from(["none", "relu", "gelu"]),
           bias=st.booleans())
    def test_dense_matmul(self, m, k, n, act, bias):
        x = RNG.standard_normal((m, k)).astype(np.float32)
        w = RNG.standard_normal((k, n)).astype(np.float32)
        b = RNG.standard_normal((n,)).astype(np.float32) if bias else None
        _check("dense_matmul", lambda: (x, w, b), activation=act)

    @settings(deadline=None, max_examples=8)
    @given(s=st.sampled_from([1, 2, 4]), n=st.sampled_from([8, 16]),
           d=st.sampled_from([4, 20, 32]))
    def test_graph_aggregate(self, s, n, d):
        blocks = (RNG.random((s, s, n, n)) < 0.2).astype(np.float32)
        h = RNG.standard_normal((s, n, d)).astype(np.float32)
        _check("graph_aggregate", lambda: (blocks, h), block_b=16)

    @settings(deadline=None, max_examples=8)
    @given(s=st.sampled_from([1, 2, 3]), n=st.sampled_from([8, 16]),
           d=st.sampled_from([4, 24]), f=st.sampled_from([4, 12]),
           act=st.sampled_from(["none", "relu"]))
    def test_fused_aggregate_extract(self, s, n, d, f, act):
        blocks = (RNG.random((s, s, n, n)) < 0.2).astype(np.float32)
        h = RNG.standard_normal((s, n, d)).astype(np.float32)
        w = RNG.standard_normal((d, f)).astype(np.float32)
        _check("fused_aggregate_extract", lambda: (blocks, h, w),
               activation=act, block_b=16)

    @settings(deadline=None, max_examples=8)
    @given(s=st.sampled_from([1, 2, 3]), n=st.sampled_from([8, 16]),
           e=st.sampled_from([12, 40]), d=st.sampled_from([4, 24]),
           op=st.sampled_from(["max", "sum"]))
    def test_gather_aggregate(self, s, n, e, d, op):
        es = RNG.integers(0, n, (s, s, e)).astype(np.int32)
        ed = RNG.integers(0, n, (s, s, e)).astype(np.int32)
        ev = RNG.random((s, s, e)) < 0.6
        h = RNG.standard_normal((s, n, d)).astype(np.float32)
        _check("gather_aggregate", lambda: (es, ed, ev, h), op=op,
               block_b=16)

    @settings(deadline=None, max_examples=4)
    @given(sq=st.sampled_from([32, 64]), heads=st.sampled_from([2, 4]),
           window=st.sampled_from([None, 24]))
    def test_attention(self, sq, heads, window):
        q = RNG.standard_normal((1, heads, sq, 16)).astype(np.float32)
        k = RNG.standard_normal((1, heads, sq, 16)).astype(np.float32)
        v = RNG.standard_normal((1, heads, sq, 16)).astype(np.float32)
        _check("attention", lambda: (q, k, v), causal=True, window=window,
               bq=32, bk=32)


class TestResolution:
    def test_env_selects_backend(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "reference")
        assert registry.resolve("dense_matmul").name == "reference"
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")   # legacy alias
        assert registry.resolve("dense_matmul").name == "reference"
        monkeypatch.delenv("REPRO_KERNEL_BACKEND")
        assert registry.resolve("dense_matmul").name == registry.DEFAULT_BACKEND

    def test_per_op_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
        monkeypatch.setenv("REPRO_KERNEL_BACKEND_GATHER_AGGREGATE", "jax")
        assert registry.resolve("gather_aggregate").name == "jax"
        assert registry.resolve("dense_matmul").name == "pallas"

    def test_explicit_override_wins(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
        assert registry.resolve("dense_matmul", "reference").name == "reference"
        be = registry.get_backend("jax")
        assert registry.resolve("dense_matmul", be) is be

    def test_composite_backend_routes_per_op(self):
        comp = registry.composite_backend(
            "reference", {"dense_matmul": "jax"})
        assert comp.dense_matmul.__self__ is registry.get_backend("jax")
        assert (comp.graph_aggregate.__self__
                is registry.get_backend("reference"))
        with pytest.raises(ValueError):
            registry.composite_backend("reference", {"nope": "jax"})

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError):
            registry.get_backend("fpga")

"""Scheduler/Server invariants (hypothesis) + end-to-end Server tests.

The invariants the continuous-batching scheduler must hold under any
traffic shape:

  * every admitted ticket completes exactly once (Ticket._resolve raises
    on a second resolution, so a clean drain IS the exactly-once proof),
  * FIFO order within equal priority on one stream,
  * expired-deadline requests resolve as Expired — they never vanish and
    never reach the engine,
  * bounded queues reject (typed Rejected, backpressure) rather than grow.

The engine here is a trivial echo so the tests exercise pure scheduling;
the GNN/LM end-to-end paths are covered at the bottom and in
tests/test_serving.py.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving import (Completed, Expired, Failed, Rejected,
                           SchedulerConfig, Server)


class EchoEngine:
    """Routes payload dicts by their 'stream' key; echoes them back."""

    def __init__(self, fail_streams=()):
        self.batches: list[tuple[object, list]] = []
        self.fail_streams = set(fail_streams)

    def route(self, payload):
        if "stream" not in payload:
            raise KeyError("payload has no stream")
        return payload["stream"]

    def step(self, key, payloads):
        if key in self.fail_streams:
            raise RuntimeError(f"engine failure on {key!r}")
        self.batches.append((key, list(payloads)))
        return [dict(p, served=True) for p in payloads]

    def served_order(self, stream=None):
        return [p["i"] for key, batch in self.batches for p in batch
                if stream is None or key == stream]


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _server(engine=None, clock=None, **cfg) -> tuple[Server, EchoEngine]:
    engine = engine or EchoEngine()
    srv = Server(engine, SchedulerConfig(**cfg),
                 clock=clock or FakeClock())
    return srv, engine


class TestSchedulerInvariants:
    @settings(deadline=None, max_examples=20)
    @given(n=st.integers(1, 24), batch=st.sampled_from([1, 3, 8]),
           streams=st.integers(1, 3))
    def test_every_admitted_ticket_completes_exactly_once(
            self, n, batch, streams):
        srv, eng = _server(max_batch_size=batch, max_queue_depth=1024)
        tickets = [srv.submit({"stream": i % streams, "i": i})
                   for i in range(n)]
        assert all(t.poll() is None for t in tickets)
        # drain raises if any ticket were resolved twice (_resolve guards)
        assert srv.drain() == n
        assert all(isinstance(t.result(), Completed) for t in tickets)
        m = srv.metrics()
        assert m["completed"] == m["admitted"] == n
        assert srv.drain() == 0          # nothing left, nothing re-runs
        assert sorted(eng.served_order()) == list(range(n))

    @settings(deadline=None, max_examples=20)
    @given(n=st.integers(1, 20), batch=st.sampled_from([1, 2, 8]),
           priority=st.integers(-2, 2))
    def test_fifo_within_equal_priority(self, n, batch, priority):
        srv, eng = _server(max_batch_size=batch, max_queue_depth=1024)
        for i in range(n):
            srv.submit({"stream": "s", "i": i}, priority=priority)
        srv.drain()
        assert eng.served_order("s") == list(range(n))

    @settings(deadline=None, max_examples=20)
    @given(n=st.integers(1, 16), deadline_ms=st.sampled_from([5.0, 50.0]),
           batch=st.sampled_from([2, 8]))
    def test_expired_deadlines_resolve_as_expired(self, n, deadline_ms,
                                                  batch):
        clock = FakeClock()
        srv, eng = _server(clock=clock, max_batch_size=batch,
                           max_queue_depth=1024)
        tickets = [srv.submit({"stream": "s", "i": i},
                              deadline_ms=deadline_ms) for i in range(n)]
        clock.t = deadline_ms / 1e3 + 0.01
        assert srv.drain() == n          # expired tickets don't vanish
        for t in tickets:
            out = t.result()
            assert isinstance(out, Expired)
            assert out.deadline_ms == deadline_ms
            assert out.waited_ms >= deadline_ms
        assert eng.batches == []         # the engine never saw them
        assert srv.metrics()["expired"] == n

    @settings(deadline=None, max_examples=20)
    @given(depth=st.integers(1, 6), extra=st.integers(1, 8))
    def test_bounded_queue_rejects_rather_than_grows(self, depth, extra):
        srv, eng = _server(max_batch_size=2, max_queue_depth=depth)
        tickets = [srv.submit({"stream": "s", "i": i})
                   for i in range(depth + extra)]
        rejected = [t for t in tickets if isinstance(t.poll(), Rejected)]
        assert len(rejected) == extra
        assert all(t.poll().kind == "backpressure" for t in rejected)
        assert srv.metrics()["peak_queue_depth"] == depth
        srv.drain()
        # exactly the admitted prefix was served, in order
        assert eng.served_order("s") == list(range(depth))


class TestSchedulerPolicy:
    def test_priority_then_edf_ordering(self):
        clock = FakeClock()
        srv, eng = _server(clock=clock, max_batch_size=1)
        srv.submit({"stream": "s", "i": 0})                       # prio 0
        srv.submit({"stream": "s", "i": 1}, priority=1,
                   deadline_ms=500.0)                             # prio 1, late dl
        srv.submit({"stream": "s", "i": 2}, priority=1,
                   deadline_ms=100.0)                             # prio 1, early dl
        srv.drain()
        assert eng.served_order("s") == [2, 1, 0]

    def test_starvation_guard_preempts_priority(self):
        clock = FakeClock()
        srv, eng = _server(clock=clock, max_batch_size=2,
                           starvation_ms=100.0)
        srv.submit({"stream": "low", "i": 0}, priority=0)
        clock.t = 0.2                    # low's head is now starving
        srv.submit({"stream": "high", "i": 1}, priority=5)
        assert srv.step(force=True) == 1
        assert eng.batches[0][0] == "low"

    def test_hybrid_formation_max_wait(self):
        clock = FakeClock()
        srv, eng = _server(clock=clock, max_batch_size=4, max_wait_ms=50.0)
        t = srv.submit({"stream": "s", "i": 0})
        assert srv.step() == 0 and t.poll() is None   # underfull, too young
        for i in range(1, 4):
            srv.submit({"stream": "s", "i": i})
        assert srv.step() == 4           # full batch dispatches immediately
        t2 = srv.submit({"stream": "s", "i": 9})
        assert srv.step() == 0
        clock.t = 0.06                   # oldest entry aged past max_wait
        assert srv.step() == 1 and isinstance(t2.poll(), Completed)

    def test_route_rejection_is_typed_not_raised(self):
        srv, eng = _server(max_batch_size=2)
        t = srv.submit({"i": 0})         # no stream -> route raises KeyError
        out = t.poll()
        assert isinstance(out, Rejected) and "KeyError" in out.reason
        assert out.kind == "invalid"
        assert srv.metrics()["rejected"] == 1

    def test_engine_failure_resolves_failed(self):
        srv, eng = _server(EchoEngine(fail_streams={"bad"}),
                           max_batch_size=4)
        tb = srv.submit({"stream": "bad", "i": 0})
        tg = srv.submit({"stream": "good", "i": 1})
        srv.drain()
        assert isinstance(tb.result(), Failed)
        assert "engine failure" in tb.result().error
        assert isinstance(tg.result(), Completed)

    def test_completed_latency_accounting(self):
        clock = FakeClock()
        srv, _ = _server(clock=clock, max_batch_size=8)
        t = srv.submit({"stream": "s", "i": 0})
        clock.t = 0.25                   # queued 250 ms before the dispatch
        out_ = srv.step(force=True)
        out = t.result()
        assert out_ == 1 and isinstance(out, Completed)
        assert out.queue_ms == pytest.approx(250.0)
        assert out.latency_ms == out.queue_ms + out.engine_ms

    def test_background_driver_thread(self):
        srv, eng = _server()             # real-enough: FakeClock at 0 is fine
        srv.start()
        try:
            outs = [srv.submit({"stream": "s", "i": i}).result(timeout_s=10.0)
                    for i in range(5)]
        finally:
            srv.stop()
        assert all(isinstance(o, Completed) for o in outs)
        assert sorted(eng.served_order("s")) == list(range(5))


class TestServerOverGNNEngine:
    @pytest.fixture(scope="class")
    def engine(self):
        from repro.gnn.models import ZooSpec
        from repro.graphs.datasets import make_dataset
        from repro.serving.gnn_engine import GNNServeEngine

        eng = GNNServeEngine(max_shard_n=64, backend="reference")
        ds = make_dataset("cora", seed=0, scale=0.05)
        eng.register_graph("cora", ds)
        eng.register_model("gcn", ZooSpec("gcn", ds.profile.feature_dim, 8,
                                          ds.profile.num_classes,
                                          num_layers=2))
        return eng

    def test_ticketed_results_match_sync_serve(self, engine):
        from repro.serving.gnn_engine import NodeRequest

        reqs = [NodeRequest("cora", np.array([i, i + 3]), model="gcn")
                for i in range(6)]
        srv = Server(engine, SchedulerConfig(max_batch_size=4))
        tickets = [srv.submit(r) for r in reqs]
        srv.drain()
        sync = engine.serve(reqs)
        for t, s in zip(tickets, sync):
            out = t.result()
            assert isinstance(out, Completed)
            np.testing.assert_array_equal(out.value.classes, s.classes)
            np.testing.assert_array_equal(out.value.node_ids, s.node_ids)
            # the Server stamps queue time onto the Prediction itself
            assert out.value.queue_ms == out.queue_ms
            assert out.value.latency_ms == pytest.approx(
                out.queue_ms + out.engine_ms)

    def test_invalid_requests_become_rejected_outcomes(self, engine):
        from repro.serving.gnn_engine import NodeRequest

        srv = Server(engine, SchedulerConfig(max_batch_size=4))
        bad_model = srv.submit(NodeRequest("cora", np.array([0]),
                                           model="nope"))
        bad_graph = srv.submit(NodeRequest("nope", np.array([0]),
                                           model="gcn"))
        bad_ids = srv.submit(NodeRequest("cora", np.array([10 ** 9]),
                                         model="gcn"))
        for t, kind in ((bad_model, "KeyError"), (bad_graph, "KeyError"),
                        (bad_ids, "IndexError")):
            out = t.poll()
            assert isinstance(out, Rejected) and kind in out.reason
        assert srv.queue_depth() == 0

    def test_submit_flush_shim_warns_and_still_works(self, engine):
        from repro.serving.gnn_engine import NodeRequest

        with pytest.warns(DeprecationWarning, match="Server"):
            engine.submit(NodeRequest("cora", np.array([1]), model="gcn"))
        with pytest.warns(DeprecationWarning, match="Server"):
            preds = engine.flush()
        assert len(preds) == 1 and preds[0].classes.shape == (1,)

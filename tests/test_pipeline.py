"""Pipeline parallelism: the GPipe schedule over the pod axis must produce
the SAME loss and gradients as the sequential model. Runs in a subprocess
with 4 forced host devices (the main pytest process keeps 1 device)."""
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import AxisType
import sys
sys.path.insert(0, "src")

from repro.configs.registry import get_smoke
from repro.dist.pipeline import (make_pipeline_loss, pipeline_microbatch,
                                 stack_pipeline_params)
from repro.models import lm
import dataclasses

cfg = get_smoke("qwen3-8b")
cfg = dataclasses.replace(cfg, n_layers=4)
params = lm.init_params(cfg, jax.random.key(0))
rng = np.random.default_rng(0)
B, S = 8, 16
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
lbls = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

# sequential reference: mean CE over all tokens
ref = float(lm.loss_fn(params, cfg, {"tokens": toks, "labels": lbls}))

mesh = jax.make_mesh((2, 2), ("pod", "data"),
                     axis_types=(AxisType.Auto,) * 2)
n_stages, n_micro = 2, 4
stage_params, rest = stack_pipeline_params(params, n_stages)
loss_fn = make_pipeline_loss(cfg, mesh, n_stages, n_micro)
mb = pipeline_microbatch({"tokens": toks, "labels": lbls}, n_micro)
with jax.set_mesh(mesh):
    got = float(jax.jit(loss_fn)(stage_params, rest,
                                 mb["tokens"], mb["labels"]))
    # gradients flow through ppermute + schedule
    g = jax.jit(jax.grad(loss_fn))(stage_params, rest,
                                   mb["tokens"], mb["labels"])
gnorm = float(sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(g)) ** 0.5)

# sequential grad on the stage-stacked structure for comparison
def seq_loss(stage_params, rest):
    k = cfg.n_layers // n_stages
    layers = []
    for s in range(n_stages):
        for j in range(k):
            layers.append(jax.tree.map(lambda a: a[s, j], stage_params))
    p = dict(rest, layers=layers)
    return lm.loss_fn(p, cfg, {"tokens": toks, "labels": lbls})

gref = jax.grad(seq_loss)(stage_params, rest)
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), g, gref)))

print(f"RESULT ref={ref:.6f} got={got:.6f} gnorm={gnorm:.4f} graderr={err:.2e}")
assert abs(ref - got) < 2e-3, (ref, got)
assert err < 2e-3, err
print("OK")
"""


@pytest.mark.slow
def test_gpipe_matches_sequential():
    import importlib.util
    import jax.sharding
    if not hasattr(jax.sharding, "AxisType"):
        pytest.skip("jax.sharding.AxisType unavailable (jax too old)")
    if importlib.util.find_spec("repro.dist") is None:
        # package genuinely absent; a broken existing repro.dist must fail
        pytest.skip("repro.dist not present in this build")
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=".")
    assert "OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]

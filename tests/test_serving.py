"""Serving engine: batched greedy decode must equal step-by-step argmax of
the full forward pass — directly and through the continuous-batching
Server (prompt-length-bucketed streams)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke
from repro.models import lm
from repro.serving import Completed, Rejected, SchedulerConfig, Server
from repro.serving.engine import Request, ServeEngine


def test_greedy_matches_forward_argmax():
    cfg = get_smoke("qwen3-8b")
    params = lm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_len=48)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(2)]
    outs = eng.generate([Request(p, max_new_tokens=6) for p in prompts])

    # reference: grow the sequence with full forward argmax each step
    for i, p in enumerate(prompts):
        seq = list(p)
        for _ in range(6):
            logits = lm.forward(params, cfg,
                                {"tokens": jnp.asarray([seq], jnp.int32)})
            seq.append(int(jnp.argmax(logits[0, -1])))
        np.testing.assert_array_equal(outs[i], np.asarray(seq[len(p):]))


def test_multicodebook_generation_shapes():
    cfg = get_smoke("musicgen-large")
    params = lm.init_params(cfg, jax.random.key(1))
    eng = ServeEngine(cfg, params, max_len=32)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size,
                            (8, cfg.n_codebooks)).astype(np.int32)
               for _ in range(2)]
    outs = eng.generate([Request(p, max_new_tokens=4) for p in prompts])
    assert outs[0].shape == (4, cfg.n_codebooks)
    assert (outs[0] >= 0).all() and (outs[0] < cfg.vocab_size).all()


def test_server_buckets_by_prompt_length_and_matches_direct_generate():
    cfg = get_smoke("qwen3-8b")
    params = lm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_len=48)
    rng = np.random.default_rng(4)
    short = [Request(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                     max_new_tokens=4) for _ in range(3)]
    long = [Request(rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                    max_new_tokens=4) for _ in range(2)]

    srv = Server(eng, SchedulerConfig(max_batch_size=2))
    tickets = [srv.submit(r) for r in short + long]
    assert srv.drain() == 5
    # prompt-length buckets: 8-token prompts form batches [2,1], 12-token [2]
    m = srv.metrics()
    assert m["batches"] == 3 and m["completed"] == 5

    for r, t in zip(short + long, tickets):
        out = t.result()
        assert isinstance(out, Completed)
        # greedy decode is deterministic, so the scheduled batching must
        # reproduce a direct single-request generate exactly
        np.testing.assert_array_equal(out.value,
                                      eng.generate([r], seed=0)[0])

    # over-long prompts are rejected at admission, typed, not raised
    too_long = srv.submit(Request(np.zeros(60, np.int32), max_new_tokens=4))
    out = too_long.poll()
    assert isinstance(out, Rejected) and "max_len" in out.reason


def test_temperature_sampling_runs():
    cfg = get_smoke("mamba2-1.3b")
    params = lm.init_params(cfg, jax.random.key(2))
    eng = ServeEngine(cfg, params, max_len=32)
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    outs = eng.generate([Request(p, max_new_tokens=5, temperature=1.0)],
                        seed=3)
    assert outs[0].shape == (5,)

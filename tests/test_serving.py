"""Serving engine: batched greedy decode must equal step-by-step argmax of
the full forward pass — directly and through the continuous-batching
Server (prompt-length-bucketed streams) — plus the GNN engine's hot
weight-reload invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke
from repro.models import lm
from repro.serving import Completed, Failed, Rejected, SchedulerConfig, Server
from repro.serving.engine import Request, ServeEngine


def test_greedy_matches_forward_argmax():
    cfg = get_smoke("qwen3-8b")
    params = lm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_len=48)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(2)]
    outs = eng.generate([Request(p, max_new_tokens=6) for p in prompts])

    # reference: grow the sequence with full forward argmax each step
    for i, p in enumerate(prompts):
        seq = list(p)
        for _ in range(6):
            logits = lm.forward(params, cfg,
                                {"tokens": jnp.asarray([seq], jnp.int32)})
            seq.append(int(jnp.argmax(logits[0, -1])))
        np.testing.assert_array_equal(outs[i], np.asarray(seq[len(p):]))


def test_multicodebook_generation_shapes():
    cfg = get_smoke("musicgen-large")
    params = lm.init_params(cfg, jax.random.key(1))
    eng = ServeEngine(cfg, params, max_len=32)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size,
                            (8, cfg.n_codebooks)).astype(np.int32)
               for _ in range(2)]
    outs = eng.generate([Request(p, max_new_tokens=4) for p in prompts])
    assert outs[0].shape == (4, cfg.n_codebooks)
    assert (outs[0] >= 0).all() and (outs[0] < cfg.vocab_size).all()


def test_server_buckets_by_prompt_length_and_matches_direct_generate():
    cfg = get_smoke("qwen3-8b")
    params = lm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_len=48)
    rng = np.random.default_rng(4)
    short = [Request(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                     max_new_tokens=4) for _ in range(3)]
    long = [Request(rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                    max_new_tokens=4) for _ in range(2)]

    srv = Server(eng, SchedulerConfig(max_batch_size=2))
    tickets = [srv.submit(r) for r in short + long]
    assert srv.drain() == 5
    # prompt-length buckets: 8-token prompts form batches [2,1], 12-token [2]
    m = srv.metrics()
    assert m["batches"] == 3 and m["completed"] == 5

    for r, t in zip(short + long, tickets):
        out = t.result()
        assert isinstance(out, Completed)
        # greedy decode is deterministic, so the scheduled batching must
        # reproduce a direct single-request generate exactly
        np.testing.assert_array_equal(out.value,
                                      eng.generate([r], seed=0)[0])

    # over-long prompts are rejected at admission, typed, not raised
    too_long = srv.submit(Request(np.zeros(60, np.int32), max_new_tokens=4))
    out = too_long.poll()
    assert isinstance(out, Rejected) and "max_len" in out.reason


class TestHotReload:
    """Server-level hot weight reload: no recompiles, cache invalidated
    exactly once, in-flight requests survive, post-reload predictions
    match a fresh compile with the new weights."""

    def _engine_and_server(self, ds, spec):
        from repro.serving.gnn_engine import GNNServeEngine
        engine = GNNServeEngine(backend="reference")
        engine.register_graph("cora", ds)
        engine.register_model("gcn", spec, seed=0)
        return engine, Server(engine, SchedulerConfig(max_batch_size=4))

    def _setup(self):
        from repro.gnn.models import ZooSpec
        from repro.graphs.datasets import make_dataset
        ds = make_dataset("cora", seed=0, scale=0.2)
        spec = ZooSpec("gcn", ds.profile.feature_dim, 8,
                       ds.profile.num_classes)
        return ds, spec

    def test_reload_matches_fresh_compile_invalidates_once(self):
        from repro import runtime
        from repro.gnn.models import init_zoo
        from repro.serving.gnn_engine import NodeRequest

        ds, spec = self._setup()
        engine, server = self._engine_and_server(ds, spec)
        ids = np.arange(6)
        t = server.submit(NodeRequest("cora", ids, "gcn"))
        server.drain()
        assert isinstance(t.result(), Completed)
        assert engine.stats["compiles"] == 1

        new_params = init_zoo(jax.random.key(42), spec)
        touched = server.reload(
            lambda eng: eng.reload_params("gcn", new_params))
        assert touched == 1
        assert engine.stats["reloads"] == 1
        assert engine.stats["logits_invalidations"] == 1
        assert server.metrics()["reloads"] == 1

        t2 = server.submit(NodeRequest("cora", ids, "gcn"))
        server.drain()
        out = t2.result()
        assert isinstance(out, Completed)
        # NO recompile happened — the jitted Executable was reused
        assert engine.stats["compiles"] == 1

        fresh = runtime.compile(spec, ds, backend="reference",
                                params=new_params)
        c_ref, p_ref = fresh.predict(ids)
        np.testing.assert_array_equal(out.value.classes, c_ref)
        np.testing.assert_allclose(out.value.probs, p_ref, atol=1e-5)

        # a model registered after the reload-compiles adopt new weights
        exe = engine.executable("gcn", "cora")
        assert all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(exe.params),
                            jax.tree.leaves(new_params)))

    def test_reload_does_not_fail_inflight_cobatched_requests(self):
        from repro.gnn.models import init_zoo
        from repro.serving.gnn_engine import NodeRequest

        ds, spec = self._setup()
        engine, server = self._engine_and_server(ds, spec)
        rng = np.random.default_rng(0)
        # queued (in-flight) BEFORE the reload; co-batched on one stream
        tickets = [server.submit(NodeRequest(
            "cora", rng.integers(0, ds.profile.num_nodes, 4), "gcn"))
            for _ in range(6)]
        assert server.queue_depth() == 6
        server.reload(lambda eng: eng.reload_params(
            "gcn", init_zoo(jax.random.key(7), spec)))
        server.drain()
        outs = [t.result() for t in tickets]
        assert all(isinstance(o, Completed) for o in outs), \
            [o for o in outs if isinstance(o, Failed)]
        assert server.metrics()["failed"] == 0

    def test_reload_validation_is_atomic(self):
        from repro.gnn.models import ZooSpec, init_zoo
        from repro.serving.gnn_engine import NodeRequest

        ds, spec = self._setup()
        engine, server = self._engine_and_server(ds, spec)
        t = server.submit(NodeRequest("cora", np.arange(3), "gcn"))
        server.drain()
        assert isinstance(t.result(), Completed)

        wrong = ZooSpec("gcn", ds.profile.feature_dim, 12,
                        ds.profile.num_classes)
        with pytest.raises(ValueError, match="reload"):
            server.reload(lambda eng: eng.reload_params(
                "gcn", init_zoo(jax.random.key(0), wrong)))
        # nothing was touched: cache still warm, params unchanged
        exe = engine.executable("gcn", "cora")
        assert exe.has_cached_probs
        assert engine.stats["reloads"] == 0
        assert engine.stats["logits_invalidations"] == 0
        with pytest.raises(KeyError):
            server.reload(lambda eng: eng.reload_params("nope", {}))


def test_mesh_unsupported_arch_rejected_typed_not_crashed():
    """dist/gnn.py only shards the linear-aggregation family; on a mesh
    engine a sage_max/gat request must come back as a typed Rejected at
    admission — not crash the engine step (which would Fail co-batched
    requests)."""
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.launch.mesh import make_mesh_for
    from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

    ds = make_dataset("cora", seed=0, scale=0.15)
    mesh = make_mesh_for(jax.device_count(), model_parallel=1)
    engine = GNNServeEngine(backend="reference", max_shard_n=128, mesh=mesh)
    engine.register_graph("cora", ds)
    engine.register_model(
        "pool", ZooSpec("sage_max", ds.profile.feature_dim, 8,
                        ds.profile.num_classes))
    engine.register_model(
        "gcn", ZooSpec("gcn", ds.profile.feature_dim, 8,
                       ds.profile.num_classes))
    server = Server(engine, SchedulerConfig(max_batch_size=4))

    bad = server.submit(NodeRequest("cora", np.arange(4), "pool"))
    out = bad.poll()                       # rejected at admission, typed
    assert isinstance(out, Rejected) and out.kind == "invalid"
    assert "sharded execution supports" in out.reason

    good = server.submit(NodeRequest("cora", np.arange(4), "gcn"))
    server.drain()
    assert isinstance(good.result(), Completed)   # engine still healthy
    assert server.metrics()["failed"] == 0


def test_temperature_sampling_runs():
    cfg = get_smoke("mamba2-1.3b")
    params = lm.init_params(cfg, jax.random.key(2))
    eng = ServeEngine(cfg, params, max_len=32)
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    outs = eng.generate([Request(p, max_new_tokens=5, temperature=1.0)],
                        seed=3)
    assert outs[0].shape == (5,)

"""Training substrate: optimizer, schedules, compression, checkpointing,
fault-tolerant loop."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.training.compression import compress_decompress
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      make_schedule)


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant",
                          warmup_steps=0, grad_clip=0)

        def loss(p):
            return jnp.sum(jnp.square(p["w"] - 1.0))

        for _ in range(200):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw_update(g, opt, params, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=1e-2)

    def test_grad_clip(self):
        params = {"w": jnp.ones(4)}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                          schedule="constant", weight_decay=0.0)
        g = {"w": jnp.full(4, 100.0)}
        _, _, stats = adamw_update(g, opt, params, cfg)
        assert float(stats["grad_norm"]) == pytest.approx(200.0)

    def test_wsd_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                          total_steps=100, decay_frac=0.2)
        s = make_schedule(cfg)
        assert float(s(jnp.int32(5))) == pytest.approx(0.5)        # warmup
        assert float(s(jnp.int32(50))) == pytest.approx(1.0)       # stable
        assert float(s(jnp.int32(100))) < 0.01                     # decayed
        # stable phase is flat (the WSD signature)
        assert float(s(jnp.int32(40))) == float(s(jnp.int32(70)))

    def test_cosine_schedule(self):
        cfg = AdamWConfig(lr=2.0, schedule="cosine", warmup_steps=10,
                          total_steps=110)
        s = make_schedule(cfg)
        assert float(s(jnp.int32(10))) == pytest.approx(2.0)
        assert float(s(jnp.int32(110))) == pytest.approx(0.0, abs=1e-6)


class TestCompression:
    def test_int8_roundtrip_bounded_error(self):
        rng = np.random.default_rng(0)
        g = {"a": jnp.asarray(rng.standard_normal(100), jnp.float32)}
        deq, err = compress_decompress(g)
        amax = float(jnp.max(jnp.abs(g["a"])))
        assert float(jnp.max(jnp.abs(deq["a"] - g["a"]))) <= amax / 127 + 1e-6

    def test_error_feedback_preserves_mean_signal(self):
        """With error feedback, the ACCUMULATED compressed signal tracks the
        accumulated true gradient (compression bias vanishes)."""
        rng = np.random.default_rng(1)
        true_sum = np.zeros(50)
        comp_sum = np.zeros(50)
        err = None
        for _ in range(200):
            g = {"g": jnp.asarray(rng.standard_normal(50) * 0.01 + 0.005,
                                  jnp.float32)}
            deq, err = compress_decompress(g, err)
            true_sum += np.asarray(g["g"])
            comp_sum += np.asarray(deq["g"])
        # residual is bounded by one quantization step, not O(T)
        resid = np.abs(true_sum - comp_sum).max()
        assert resid < 0.01, resid

    def test_sgd_with_compression_converges(self):
        w = jnp.asarray([2.0, -3.0])
        err = None
        for _ in range(300):
            g = {"w": 2 * (w - 1.0)}
            deq, err = compress_decompress(g, err)
            w = w - 0.05 * deq["w"]
        np.testing.assert_allclose(np.asarray(w), 1.0, atol=1e-2)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(5, dtype=jnp.float32),
                "b": [jnp.ones((2, 2)), jnp.zeros(3, jnp.int32)]}
        mgr.save(tree, 10)
        out, step = mgr.restore_latest(tree)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(out["b"][1]),
                                      np.asarray(tree["b"][1]))

    def test_retention_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.zeros(1)}
        for s in (1, 2, 3, 4):
            mgr.save({"a": jnp.full(1, float(s))}, s)
        assert mgr.latest_step() == 4
        steps = sorted(int(p.name.split("_")[1])
                       for p in tmp_path.glob("step_*"))
        assert steps == [3, 4]
        out, _ = mgr.restore_latest(tree)
        assert float(out["a"][0]) == 4.0

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save({"a": jnp.zeros(2)}, 5)
        # simulate a crash mid-save: dir without meta.json
        bad = tmp_path / "step_00000009"
        bad.mkdir()
        assert mgr.latest_step() == 5

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save({"a": jnp.arange(3)}, 7)
        mgr.wait()
        assert mgr.latest_step() == 7


class TestTrainLoopResume:
    def test_resume_after_preemption(self, tmp_path):
        """Kill the loop mid-run (simulated), restart, verify the loss
        continues from the checkpoint, not from scratch."""
        from repro.configs.registry import get_smoke
        from repro.models import lm
        from repro.training.optimizer import adamw_init
        from repro.training.train_loop import TrainLoop, make_train_step

        cfg = get_smoke("qwen2.5-3b")
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
        rng = np.random.default_rng(0)
        data = [
            {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32)}
            for _ in range(8)
        ]
        params = lm.init_params(cfg, jax.random.key(0))
        opt = adamw_init(params)
        mgr = CheckpointManager(str(tmp_path), keep=2)
        loop = TrainLoop(cfg, opt_cfg, lambda s: data[s % len(data)],
                         ckpt_manager=mgr, ckpt_every=4, log_every=100)
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False))
        # run 6 steps -> checkpoint at 4
        p1, o1, _ = loop.run(params, opt, 6, train_step=step_fn,
                             log=lambda *_: None)
        assert mgr.latest_step() == 4
        # "restart": fresh params, loop must restore step 4 and continue
        params2 = lm.init_params(cfg, jax.random.key(99))
        opt2 = adamw_init(params2)
        p2, o2, _ = loop.run(params2, opt2, 8, train_step=step_fn,
                             log=lambda *_: None)
        assert mgr.latest_step() == 8
        assert int(o2["step"]) == 8

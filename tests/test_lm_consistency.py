"""Serving-path invariants: prefill + decode must reproduce the full
forward pass position-for-position (exactly for dense/hybrid/SSM archs;
for MoE archs with no-drop capacity)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_smoke
from repro.models import lm


def _nodrop(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.num_experts)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_match_forward(arch):
    cfg = _nodrop(get_smoke(arch))
    params = lm.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    b, s, max_len = 2, 24, 32

    if cfg.input_mode == "embeddings":
        emb = jnp.asarray(rng.standard_normal((b, s + 1, cfg.d_model)),
                          jnp.float32)
        full_batch = {"embeddings": emb}
        pre_batch = {"embeddings": emb[:, :s]}
        dec_batch = {"embeddings": emb[:, s:s + 1], "pos": jnp.int32(s)}
    else:
        shape = (b, s + 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s + 1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)
        full_batch = {"tokens": toks}
        pre_batch = {"tokens": toks[:, :s]}
        dec_batch = {"tokens": toks[:, s:s + 1], "pos": jnp.int32(s)}

    full = lm.forward(params, cfg, full_batch)
    logits_pf, caches = lm.prefill(params, cfg, pre_batch, max_len)
    np.testing.assert_allclose(np.asarray(logits_pf[:, 0]),
                               np.asarray(full[:, s - 1]), atol=2e-4, rtol=2e-4)
    logits_dec, caches = lm.decode_step(params, cfg, dec_batch, caches)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(full[:, s]), atol=5e-4, rtol=5e-4)


def test_local_attention_ring_buffer():
    """Decode past the window: ring buffer must equal full-buffer attention
    restricted to the window."""
    cfg = get_smoke("recurrentgemma-2b")   # window 16
    params = lm.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    b, total = 1, 40
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, total)), jnp.int32)
    full = lm.forward(params, cfg, {"tokens": toks})
    # prefill 24, then decode 16 more one-by-one (crosses the ring boundary)
    s = 24
    _, caches = lm.prefill(params, cfg, {"tokens": toks[:, :s]}, max_len=total)
    for pos in range(s, total):
        logits, caches = lm.decode_step(
            params, cfg, {"tokens": toks[:, pos:pos + 1],
                          "pos": jnp.int32(pos)}, caches)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, pos]),
                                   atol=5e-4, rtol=5e-4)


def test_mrope_text_degenerates_to_rope():
    """M-RoPE with identical (t,h,w) ids == standard RoPE (paper property
    of Qwen2-VL): verify via the attention module directly."""
    from repro.nn.rope import apply_mrope, apply_rope
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 4, 8, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    pos3 = jnp.broadcast_to(pos, (3, 2, 8))
    a = apply_rope(x, pos, 10000.0)
    b = apply_mrope(x, pos3, 10000.0, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_ssd_matches_sequential_scan():
    """Chunked SSD (matmul form) == naive sequential state recurrence."""
    from repro.models.config import SSMConfig
    from repro.nn.ssd import _ssd_scan
    rng = np.random.default_rng(3)
    bt, l, h, p, n = 2, 24, 4, 8, 16
    cfg = get_smoke("mamba2-1.3b")
    cfg = dataclasses.replace(cfg, ssm=SSMConfig(
        d_state=n, head_dim=p, expand=2, n_groups=1, chunk_size=8))
    x = jnp.asarray(rng.standard_normal((bt, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (bt, l, h)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(0.0, 1.0, (h,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bt, l, 1, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((bt, l, 1, n)), jnp.float32)
    y, state = _ssd_scan(x, dt, a_log, b, c, cfg)

    # naive recurrence
    A = -np.exp(np.asarray(a_log))
    st = np.zeros((bt, h, p, n), np.float64)
    ys = np.zeros((bt, l, h, p), np.float64)
    xn, dtn, bn, cn = map(np.asarray, (x, dt, b, c))
    for t in range(l):
        da = np.exp(dtn[:, t] * A[None])                     # (bt,h)
        st = st * da[..., None, None] + np.einsum(
            "bh,bhp,bn->bhpn", dtn[:, t], xn[:, t], bn[:, t, 0])
        ys[:, t] = np.einsum("bhpn,bn->bhp", st, cn[:, t, 0])
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), st, atol=1e-3, rtol=1e-3)


def test_rglru_matches_sequential():
    """Associative-scan RG-LRU == per-step recurrence."""
    from repro.nn.rglru import rglru_apply, rglru_decode, rglru_cache_struct
    cfg = get_smoke("recurrentgemma-2b")
    from repro.nn.layers import init_leaf
    from repro.nn.rglru import rglru_struct
    p = rglru_struct(init_leaf(jax.random.key(4), jnp.float32), "t", cfg)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 12, cfg.d_model)), jnp.float32)
    full = rglru_apply(p, x, cfg)
    cache = rglru_cache_struct(cfg, 2)
    outs = []
    for t in range(12):
        o, cache = rglru_decode(p, x[:, t:t + 1], cfg, cache)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               atol=2e-4, rtol=2e-4)

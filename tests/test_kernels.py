"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.dense_engine import dense_engine_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_gnn import fused_gnn_layer
from repro.kernels.seg_gather import seg_gather_aggregate
from repro.kernels.shard_spmm import shard_spmm

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (64, 64, 64, 32, 32, 32),
    (128, 256, 64, 64, 64, 64),
    (32, 96, 160, 32, 32, 32),
])
def test_dense_engine(m, k, n, bm, bk, bn, dtype):
    x, w, b = _rand((m, k), dtype), _rand((k, n), dtype), _rand((n,), dtype)
    out = dense_engine_matmul(x, w, b, activation="relu", bm=bm, bn=bn, bk=bk)
    exp = ref.dense_engine(x, w, b, activation="relu")
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,n,d,bb", [(2, 16, 32, 16), (4, 8, 64, 32), (3, 32, 48, 16)])
def test_shard_spmm(s, n, d, bb, dtype):
    a = (RNG.random((s, s, n, n)) < 0.2).astype(np.float32)
    h = _rand((s, n, d), dtype)
    out = shard_spmm(a, h, block_b=bb)
    exp = ref.shard_spmm(a, h)
    tol = 1e-4 if dtype == np.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("s,n,d,f,bb", [(2, 16, 32, 8, 16), (3, 8, 64, 24, 16)])
def test_fused_gnn(s, n, d, f, bb):
    a = (RNG.random((s, s, n, n)) < 0.2).astype(np.float32)
    h = _rand((s, n, d), np.float32)
    w = _rand((d, f), np.float32)
    out = fused_gnn_layer(a, h, w, block_b=bb, activation="relu")
    exp = ref.fused_gnn(a, h, w, activation="relu")
    np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("op", ["max", "sum"])
@pytest.mark.parametrize("s,n,e,d,bb", [(2, 16, 24, 32, 16), (3, 8, 40, 16, 16)])
def test_seg_gather(op, s, n, e, d, bb):
    es = RNG.integers(0, n, (s, s, e)).astype(np.int32)
    ed = RNG.integers(0, n, (s, s, e)).astype(np.int32)
    ev = RNG.random((s, s, e)) < 0.6
    h = _rand((s, n, d), np.float32)
    out = seg_gather_aggregate(es, ed, ev, h, op=op, block_b=bb)
    # oracle: combine per-pair refs across the src axis
    import os
    os.environ["REPRO_KERNEL_BACKEND"] = "ref"
    try:
        exp = ops.gather_aggregate(es, ed, ev, h, op=op)
    finally:
        os.environ.pop("REPRO_KERNEL_BACKEND")
    np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,sq,skv,dh,window", [
    (1, 4, 4, 64, 64, 32, None),
    (2, 4, 2, 64, 64, 32, None),     # GQA
    (1, 2, 1, 32, 128, 16, None),    # cross lengths (q suffix of kv)
    (1, 4, 4, 128, 128, 32, 48),     # local window
])
def test_flash_attention(b, hq, hkv, sq, skv, dh, window, dtype):
    q = _rand((b, hq, sq, dh), dtype)
    k = _rand((b, hkv, skv, dh), dtype)
    v = _rand((b, hkv, skv, dh), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, bq=32, bk=32)
    exp = ref.flash_attention(q, k, v, causal=True, window=window)
    tol = 2e-4 if dtype == np.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(1, 3), n=st.sampled_from([8, 16]),
    d=st.sampled_from([16, 32]), bb=st.sampled_from([8, 16]),
    seed=st.integers(0, 2 ** 16),
)
def test_spmm_matches_dense_matmul(s, n, d, bb, seed):
    """Property: shard-grid SpMM == the flat (N×N)·(N×D) matmul."""
    r = np.random.default_rng(seed)
    a = (r.random((s, s, n, n)) < 0.3).astype(np.float32)
    h = r.standard_normal((s, n, d)).astype(np.float32)
    out = shard_spmm(a, h, block_b=bb)
    # flatten the block-structured adjacency to (S*n, S*n)
    a_flat = a.transpose(0, 2, 1, 3).reshape(s * n, s * n)
    exp = (a_flat @ h.reshape(s * n, d)).reshape(s, n, d)
    np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([8, 16, 32]), d=st.sampled_from([32, 64]),
    seed=st.integers(0, 2 ** 16),
)
def test_blocking_invariance(b, d, seed):
    """Property: the paper's core claim — dimension-blocking does not change
    the result, only the schedule. Any B must give identical output."""
    r = np.random.default_rng(seed)
    s, n = 2, 16
    a = (r.random((s, s, n, n)) < 0.3).astype(np.float32)
    h = r.standard_normal((s, n, d)).astype(np.float32)
    full = shard_spmm(a, h, block_b=d)      # conventional dataflow (B = D)
    blocked = shard_spmm(a, h, block_b=b)   # dimension-blocked
    np.testing.assert_allclose(full, blocked, atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), act=st.sampled_from(["none", "relu", "gelu"]))
def test_fusion_invariance(seed, act):
    """Property: fused engine == GraphEngine then DenseEngine."""
    r = np.random.default_rng(seed)
    s, n, d, f = 2, 8, 32, 16
    a = (r.random((s, s, n, n)) < 0.3).astype(np.float32)
    h = r.standard_normal((s, n, d)).astype(np.float32)
    w = r.standard_normal((d, f)).astype(np.float32)
    fused = fused_gnn_layer(a, h, w, block_b=16, activation=act)
    agg = shard_spmm(a, h, block_b=16)
    twostep = ref.dense_engine(agg.reshape(s * n, d), w, activation=act)
    np.testing.assert_allclose(fused, twostep.reshape(s, n, f),
                               atol=1e-3, rtol=1e-3)

"""Perf-model + paper-benchmark validation: the analytical platform model
must reproduce the paper's headline claims within tolerance."""

from benchmarks.paper_tables import (bench_fig3, bench_fig4, bench_fig5,
                                     bench_table1, bench_table5)


def test_table1_formulas_exact():
    _, derived = bench_table1()
    assert derived["max_read_rel_err"] == 0.0


def test_fig3_average_speedups_within_band():
    _, d = bench_fig3()
    assert abs(d["avg_speedup_blocked"] - 8.0) / 8.0 < 0.25
    assert abs(d["avg_speedup_noblock"] - 4.2) / 4.2 < 0.25
    # blocking roughly doubles performance (the paper's core claim)
    assert 1.5 < d["blocking_gain"] < 2.6


def test_fig3_speedup_range_matches_paper():
    rows, _ = bench_fig3()
    # paper: 5.7x - 37x range over the GPU (Fig 3); allow our model's
    # conservative low end for pool workloads
    speeds = [r["speedup_blocked"] for r in rows]
    assert min(speeds) > 1.0
    assert max(speeds) < 40.0


def test_table5_vs_hygcn():
    rows, d = bench_table5()
    assert abs(d["avg_vs_hygcn"] - 3.15) / 3.15 < 0.25
    # per-dataset ordering preserved: cora > citeseer > pubmed
    vals = {r["dataset"]: r["vs_hygcn_blocked"] for r in rows}
    assert vals["cora"] > vals["pubmed"]
    # without blocking, HyGCN wins citeseer (its sparsity elimination)
    nb = {r["dataset"]: r["vs_hygcn_noblock"] for r in rows}
    assert nb["citeseer"] < 1.0


def test_fig4_knee_at_dense_width():
    rows, d = bench_fig4()
    assert d["best_B"] == 64
    by_b = {r["B"]: r["avg_speedup"] for r in rows}
    assert by_b[16] < by_b[64]          # below systolic width hurts
    assert by_b[512] < by_b[64]         # huge blocks hurt (fewer nodes)


def test_fig5_investment_crossover():
    rows, d = bench_fig5()
    assert d["winner_small_hidden"] == "2x_bw"
    assert d["winner_large_hidden"] == "2x_dense"
    by_h = {r["hidden"]: r for r in rows}
    # dense-engine benefit grows monotonically with hidden size
    assert by_h[1024]["2x_dense"] > by_h[64]["2x_dense"]

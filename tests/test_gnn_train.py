"""End-to-end GNN training (`runtime.fit`): accuracy on cora, mini-batch
sampling, checkpoint/resume determinism, and hot reload of trained
weights into the compiled Executable."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import runtime
from repro.checkpoint.manager import CheckpointManager
from repro.gnn.models import ZooSpec
from repro.graphs.datasets import make_dataset
from repro.graphs.sampler import NeighborSampler
from repro.runtime.executable import _flatten_params, _unflatten_params


def _bitwise_equal(tree_a, tree_b) -> bool:
    la, lb = jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(la, lb))


class TestFitAccuracy:
    @pytest.mark.parametrize("arch", ["gcn", "sage_mean", "gin"])
    def test_trains_cora_to_accuracy(self, arch):
        """The acceptance gate: >= 0.75 train accuracy on (synthetic)
        cora within 200 full-batch steps on the reference backend."""
        ds = make_dataset("cora", seed=0)
        spec = ZooSpec(arch, ds.profile.feature_dim, 16,
                       ds.profile.num_classes)
        res = runtime.fit(spec, ds, steps=150, lr=1e-2,
                          backend="reference", log=lambda s: None)
        acc = res.train_accuracy()
        assert acc >= 0.75, f"{arch}: train acc {acc:.3f} < 0.75"
        # losses monotone-ish: end well below start
        assert res.history[-1][1] < 0.7 * res.history[0][1]
        # the trained weights were hot-swapped into the Executable
        assert _bitwise_equal(res.executable.params, res.params)
        classes, probs = res.executable.predict([0, 1, 2])
        assert classes.shape == (3,)

    def test_fit_requires_labels_and_features(self):
        ds = make_dataset("cora", seed=0, scale=0.1)
        spec = ZooSpec("gcn", ds.profile.feature_dim, 8,
                       ds.profile.num_classes)
        with pytest.raises(ValueError, match="labels"):
            runtime.fit(spec, (ds.edges, ds.profile.num_nodes, ds.features),
                        steps=1, backend="reference", log=lambda s: None)
        with pytest.raises(ValueError, match="features"):
            runtime.fit(spec, (ds.edges, ds.profile.num_nodes),
                        labels=ds.labels, steps=1, backend="reference",
                        log=lambda s: None)


class TestMiniBatch:
    def test_sampler_is_deterministic_and_budgeted(self):
        ds = make_dataset("citeseer", seed=0, scale=0.3)
        smp = NeighborSampler(ds.edges, ds.profile.num_nodes,
                              batch_nodes=16, fanout=(4, 3), seed=7)
        a, b = smp.sample(5), smp.sample(5)
        assert np.array_equal(a.nodes, b.nodes)
        assert np.array_equal(a.edges, b.edges)
        c = smp.sample(6)
        assert not np.array_equal(a.nodes, c.nodes)
        # fixed shapes: budget-sized node set, seeds first
        assert a.nodes.shape == (smp.budget,)
        assert a.seed_mask[:16].all() or a.seed_mask.sum() <= 16
        assert a.edges.shape[0] <= smp.edge_cap
        # every edge endpoint is a real (non-padding) local id
        if a.edges.size:
            assert a.edges.max() < a.num_real

    def test_sampler_handles_zero_in_degree_tail_nodes(self):
        """A frontier node whose CSR offset sits at E (no in-edges, all
        edge dsts below its id) used to read past src_sorted before the
        validity mask applied — IndexError on real training data."""
        edges = np.array([[0, 1]], dtype=np.int64)
        smp = NeighborSampler(edges, 3, batch_nodes=3, fanout=(2,), seed=0)
        batch = smp.sample(0)               # must not raise
        assert batch.num_real >= 1
        # edge-free graph is fine too
        empty = NeighborSampler(np.empty((0, 2), np.int64), 4,
                                batch_nodes=2, fanout=(2,))
        assert empty.sample(0).edges.shape[0] == 0

    def test_sampler_dedupes_seeds_when_pool_is_small(self):
        """batch_nodes > |seed pool| draws with replacement; duplicate
        seeds must collapse to one local slot each (a duplicate slot
        would sit in the loss mask with no in-edges)."""
        ds = make_dataset("cora", seed=0, scale=0.1)
        pool = np.arange(4, dtype=np.int64)
        smp = NeighborSampler(ds.edges, ds.profile.num_nodes,
                              batch_nodes=16, fanout=(3,), seed_ids=pool)
        batch = smp.sample(0)
        n_seeds = int(batch.seed_mask.sum())
        assert n_seeds <= pool.size
        seeds = batch.nodes[:n_seeds]
        assert len(np.unique(seeds)) == n_seeds

    def test_minibatch_fit_learns(self):
        ds = make_dataset("cora", seed=0, scale=0.5)
        spec = ZooSpec("gcn", ds.profile.feature_dim, 16,
                       ds.profile.num_classes)
        res = runtime.fit(spec, ds, steps=30, lr=1e-2, batch_nodes=64,
                          fanout=(5, 5), backend="reference",
                          log=lambda s: None)
        assert np.isfinite(res.history[-1][1])
        assert res.history[-1][1] < res.history[0][1]

    def test_minibatch_rejects_mesh(self):
        ds = make_dataset("cora", seed=0, scale=0.1)
        spec = ZooSpec("gcn", ds.profile.feature_dim, 8,
                       ds.profile.num_classes)
        from repro.launch.mesh import make_mesh_for
        mesh = make_mesh_for(1, model_parallel=1)
        with pytest.raises(NotImplementedError, match="mini-batch"):
            runtime.fit(spec, ds, steps=1, batch_nodes=8, mesh=mesh,
                        backend="reference", log=lambda s: None)


class TestCheckpointResume:
    def test_resume_is_bitwise_deterministic(self, tmp_path):
        """Train k steps, checkpoint, resume in a fresh fit run: params
        AND optimizer state must be bitwise equal to an uninterrupted
        run of the same total length."""
        ds = make_dataset("cora", seed=0, scale=0.2)
        spec = ZooSpec("gcn", ds.profile.feature_dim, 8,
                       ds.profile.num_classes)
        kw = dict(backend="reference", log=lambda s: None)

        uninterrupted = runtime.fit(spec, ds, steps=8, **kw)

        d = str(tmp_path / "ckpt")
        runtime.fit(spec, ds, steps=4, ckpt_manager=CheckpointManager(d),
                    ckpt_every=4, **kw)
        resumed = runtime.fit(spec, ds, steps=8,
                              ckpt_manager=CheckpointManager(d),
                              ckpt_every=100, **kw)

        assert _bitwise_equal(uninterrupted.params, resumed.params)
        assert _bitwise_equal(uninterrupted.opt_state, resumed.opt_state)
        assert int(resumed.opt_state["step"]) == 8

    def test_minibatch_resume_replays_sampler(self, tmp_path):
        """The sampler is seeded by step, so a resumed mini-batch run
        sees the exact batches the uninterrupted run saw."""
        ds = make_dataset("cora", seed=0, scale=0.2)
        spec = ZooSpec("gcn", ds.profile.feature_dim, 8,
                       ds.profile.num_classes)
        kw = dict(backend="reference", batch_nodes=16, fanout=(4,),
                  log=lambda s: None)

        uninterrupted = runtime.fit(spec, ds, steps=6, **kw)
        d = str(tmp_path / "ckpt")
        runtime.fit(spec, ds, steps=3, ckpt_manager=CheckpointManager(d),
                    ckpt_every=3, **kw)
        resumed = runtime.fit(spec, ds, steps=6,
                              ckpt_manager=CheckpointManager(d),
                              ckpt_every=100, **kw)
        assert _bitwise_equal(uninterrupted.params, resumed.params)
        assert _bitwise_equal(uninterrupted.opt_state, resumed.opt_state)

    def test_unflatten_roundtrips_optimizer_state_trees(self):
        """_unflatten_params must rebuild the full train state — params
        lists AND the mirrored optimizer moment trees + scalar step."""
        from repro.training.optimizer import adamw_init

        spec = ZooSpec("gin", 6, 8, 3)
        from repro.gnn.models import init_zoo
        params = init_zoo(jax.random.key(0), spec)
        state = {"params": params, "opt": adamw_init(params)}
        state["opt"]["step"] = jnp.asarray(5, jnp.int32)

        rebuilt = _unflatten_params(_flatten_params(state))
        assert _bitwise_equal(state, rebuilt)
        assert isinstance(rebuilt["params"]["layers"], list)
        assert isinstance(rebuilt["opt"]["m"]["layers"], list)
        assert int(rebuilt["opt"]["step"]) == 5

    def test_save_load_state_roundtrip(self, tmp_path):
        ds = make_dataset("cora", seed=0, scale=0.15)
        spec = ZooSpec("gcn", ds.profile.feature_dim, 8,
                       ds.profile.num_classes)
        res = runtime.fit(spec, ds, steps=3, backend="reference",
                          log=lambda s: None)
        path = tmp_path / "state.npz"
        res.trainable.save_state(path)

        fresh = runtime.fit(spec, ds, steps=0, backend="reference",
                            log=lambda s: None)
        state = fresh.trainable.load_state(path)
        assert _bitwise_equal(state["params"], res.params)
        assert _bitwise_equal(fresh.trainable.opt_state, res.opt_state)
        # the reload propagated into the wrapped Executable
        assert _bitwise_equal(fresh.executable.params, res.params)


class TestHotReloadExecutable:
    def test_update_params_validates_and_invalidates_once(self):
        ds = make_dataset("cora", seed=0, scale=0.15)
        spec = ZooSpec("gcn", ds.profile.feature_dim, 8,
                       ds.profile.num_classes)
        exe = runtime.compile(spec, ds, backend="reference")
        exe.predict([0, 1])
        assert exe.has_cached_probs

        from repro.gnn.models import init_zoo
        exe.update_params(init_zoo(jax.random.key(9), spec))
        assert not exe.has_cached_probs        # invalidated by the swap

        bad_spec = ZooSpec("gcn", ds.profile.feature_dim, 12,
                           ds.profile.num_classes)
        with pytest.raises(ValueError, match="shape"):
            exe.update_params(init_zoo(jax.random.key(0), bad_spec))
        with pytest.raises(ValueError, match="tree"):
            exe.update_params(
                init_zoo(jax.random.key(0),
                         ZooSpec("gin", ds.profile.feature_dim, 8,
                                 ds.profile.num_classes)))

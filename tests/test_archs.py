"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward + one real train step on CPU, asserting output shapes
and finiteness."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, SHAPES, get_config, get_smoke, shape_applicable
from repro.models import lm
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import make_train_step

RNG = np.random.default_rng(0)


def _batch(cfg, b=2, s=16, labels=True):
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeddings"] = jnp.asarray(
            RNG.standard_normal((b, s, cfg.d_model)), jnp.float32)
        if cfg.rope_kind == "mrope":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (3, b, s))
    else:
        shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s)
        batch["tokens"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, shape), jnp.int32)
    if labels:
        lshape = (b, s, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s)
        batch["labels"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, lshape), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    params = lm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits = lm.forward(params, cfg, batch)
    want = (2, 16, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks > 1 \
        else (2, 16, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke(arch)
    params = lm.init_params(cfg, jax.random.key(1))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10), remat=False))
    batch = _batch(cfg)
    p1, o1, m1 = step(params, opt, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    # params actually moved and loss does not explode
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p1))
    assert max(moved) > 0
    assert float(m2["loss"]) < float(m1["loss"]) * 1.5


@pytest.mark.parametrize("arch", ARCHS)
def test_full_configs_match_assignment(arch):
    """The FULL configs must carry the exact assigned hyper-parameters."""
    spec = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (arch, got, spec)


def test_param_counts_plausible():
    """Analytic parameter counts should land near the archs' nameplates."""
    expect = {  # (total_B, tolerance_frac)
        "llama4-scout-17b-a16e": (109e9, 0.15),
        "qwen2-moe-a2.7b": (14.3e9, 0.15),
        "qwen3-8b": (8.2e9, 0.15),
        "command-r-plus-104b": (104e9, 0.15),
        "mamba2-1.3b": (1.3e9, 0.25),
        "recurrentgemma-2b": (2.7e9, 0.25),
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).num_params()
        assert abs(got - want) / want < tol, (arch, got, want)
    # MoE active << total
    l4 = get_config("llama4-scout-17b-a16e")
    assert l4.active_params() < 0.25 * l4.num_params()


def test_long_500k_applicability():
    subq = {a for a in ARCHS if shape_applicable(a, "long_500k")[0]}
    assert subq == {"recurrentgemma-2b", "mamba2-1.3b"}
    assert len(SHAPES) == 4 and len(ARCHS) == 10  # 40 assigned cells


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "recurrentgemma-2b",
                                  "mamba2-1.3b", "musicgen-large"])
def test_scanned_forward_matches_unrolled(arch):
    """The dry-run proof artifact (scan over stacked layers) must be
    numerically identical to the unrolled model."""
    cfg = get_smoke(arch)
    params = lm.init_params(cfg, jax.random.key(2))
    batch = _batch(cfg, labels=False)
    ref = lm.forward(params, cfg, batch)
    # restack params to the scanned layout
    p = lm.pattern_period(cfg)
    nf = cfg.n_layers // p
    stack = []
    for j in range(p):
        group = [params["layers"][j + k * p] for k in range(nf)]
        stack.append(jax.tree.map(lambda *ls: jnp.stack(ls), *group))
    scanned = {k: v for k, v in params.items() if k != "layers"}
    scanned["stack"] = tuple(stack)
    scanned["trail"] = params["layers"][nf * p:]
    got = lm.forward_scanned(scanned, cfg, batch)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-4, rtol=2e-4)

"""repro.runtime: the compile() -> Executable API, plan serialization +
memoization, backend parity per zoo arch, and the deprecation shims."""
import json
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import runtime
from repro.gnn import executor
from repro.gnn.models import ARCHS, ZooSpec
from repro.graphs.datasets import TABLE2_DATASETS, make_dataset

# small enough that pallas interpret mode stays fast, scaled per dataset so
# every Table-II profile is exercised with a multi-shard grid
SCALES = {"cora": 0.02, "citeseer": 0.015, "pubmed": 0.003}


def _spec(arch, prof, hidden=8):
    return ZooSpec(arch, prof.feature_dim, hidden, prof.num_classes,
                   num_layers=2, heads=2)


class TestCompile:
    def test_executable_owns_plan_graph_params(self):
        ds = make_dataset("cora", seed=0, scale=0.05)
        exe = runtime.compile(_spec("gcn", ds.profile), ds,
                              backend="reference", max_shard_n=64)
        assert exe.plan.arch == "gcn"
        assert exe.backend_name == "reference"
        assert exe.gt.S == exe.plan.layers[0].S or exe.gt.n <= 64
        logits = exe.forward()
        assert logits.shape == (ds.profile.num_nodes, ds.profile.num_classes)
        assert "Executable[gcn]" in exe.summary()

    def test_forward_accepts_params_and_features(self):
        ds = make_dataset("cora", seed=0, scale=0.05)
        exe = runtime.compile(_spec("gcn", ds.profile), ds,
                              backend="reference", max_shard_n=64)
        base = np.asarray(exe.forward())
        # explicit features: same numbers
        np.testing.assert_allclose(
            np.asarray(exe.forward(features=ds.features)), base,
            atol=1e-6, rtol=1e-6)
        # fresh params: different numbers, same differentiable entry point
        p2 = runtime.compile(_spec("gcn", ds.profile), ds,
                             backend="reference", max_shard_n=64,
                             seed=3).params
        assert not np.allclose(np.asarray(exe.forward(p2)), base)
        grads = jax.grad(lambda p: exe.forward(p).sum())(exe.params)
        assert jax.tree_util.tree_structure(
            grads) == jax.tree_util.tree_structure(exe.params)

    def test_node_batch_entry_points(self):
        ds = make_dataset("citeseer", seed=0, scale=0.05)
        exe = runtime.compile(_spec("gat", ds.profile), ds,
                              backend="reference", max_shard_n=64)
        ids = np.array([0, 5, 11])
        full = np.asarray(exe.forward())
        np.testing.assert_allclose(np.asarray(exe.forward_nodes(ids)),
                                   full[ids], atol=1e-6)
        assert not exe.has_cached_probs
        classes, probs = exe.predict(ids)
        assert exe.has_cached_probs
        np.testing.assert_array_equal(classes,
                                      np.argmax(full[ids], axis=-1))
        assert np.all((probs > 0) & (probs <= 1))
        exe.invalidate()
        assert not exe.has_cached_probs

    def test_graph_tuple_input_and_fingerprint_sharing(self):
        ds = make_dataset("cora", seed=0, scale=0.05)
        store = runtime.GraphStore()
        spec = _spec("gcn", ds.profile)
        graph = (ds.edges, ds.profile.num_nodes, ds.features)
        e1 = runtime.compile(spec, graph, backend="reference",
                             max_shard_n=64, store=store)
        e2 = runtime.compile(spec, graph, backend="reference",
                             max_shard_n=64, store=store)
        # identical content -> same fingerprint -> one shard build
        assert store.stats["misses"] == 1 and store.stats["hits"] == 1
        assert e1.gt is e2.gt

    def test_fingerprint_distinguishes_features(self):
        """Regression: same topology + different features must not share a
        GraphStore entry (the entry caches the grouped feature tensor)."""
        ds = make_dataset("cora", seed=0, scale=0.05)
        store = runtime.GraphStore()
        spec = _spec("gcn", ds.profile)
        feats2 = ds.features + 1.0
        e1 = runtime.compile(spec, (ds.edges, ds.profile.num_nodes,
                                    ds.features), backend="reference",
                             max_shard_n=64, store=store, seed=0)
        e2 = runtime.compile(spec, (ds.edges, ds.profile.num_nodes, feats2),
                             backend="reference", max_shard_n=64,
                             store=store, seed=0)
        assert e1.graph_key != e2.graph_key
        assert not np.allclose(np.asarray(e1.forward()),
                               np.asarray(e2.forward()))

    def test_per_op_env_override_reaches_compile(self, monkeypatch):
        """Regression: REPRO_KERNEL_BACKEND_<OP> must survive into the
        pinned Executable when no explicit backend is passed."""
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "reference")
        monkeypatch.setenv("REPRO_KERNEL_BACKEND_GATHER_AGGREGATE", "jax")
        ds = make_dataset("cora", seed=0, scale=0.05)
        exe = runtime.compile(_spec("sage_max", ds.profile), ds,
                              max_shard_n=64)
        assert exe.backend.gather_aggregate.__self__ is \
            runtime.get_backend("jax")
        assert exe.backend.dense_matmul.__self__ is \
            runtime.get_backend("reference")
        # an explicit backend argument beats the per-op env override
        pinned = runtime.compile(_spec("sage_max", ds.profile), ds,
                                 backend="reference", max_shard_n=64)
        assert pinned.backend is runtime.get_backend("reference")

    def test_op_backends_override(self):
        ds = make_dataset("cora", seed=0, scale=0.05)
        exe = runtime.compile(_spec("sage_max", ds.profile), ds,
                              backend="reference",
                              op_backends={"gather_aggregate": "jax"},
                              max_shard_n=64)
        assert exe.backend_name.startswith("composite(reference")
        ref_exe = runtime.compile(_spec("sage_max", ds.profile), ds,
                                  backend="reference", max_shard_n=64)
        np.testing.assert_allclose(np.asarray(exe.forward()),
                                   np.asarray(ref_exe.forward()),
                                   atol=1e-5, rtol=1e-5)

    def test_params_roundtrip(self, tmp_path):
        ds = make_dataset("cora", seed=0, scale=0.05)
        exe = runtime.compile(_spec("gin", ds.profile), ds,
                              backend="reference", max_shard_n=64)
        before = np.asarray(exe.forward())
        exe.save_params(tmp_path / "p.npz")
        exe.save_plan(tmp_path / "plan.json")
        # perturb, then restore from disk
        exe.set_params(jax.tree_util.tree_map(lambda x: x * 0, exe.params))
        assert not np.allclose(np.asarray(exe.forward()), before)
        exe.load_params(tmp_path / "p.npz")
        np.testing.assert_allclose(np.asarray(exe.forward()), before,
                                   atol=1e-6)
        plan = executor.ModelPlan.from_json(
            json.loads((tmp_path / "plan.json").read_text()))
        assert plan == exe.plan


class TestGraphStoreEviction:
    def test_executable_serves_after_lru_eviction_and_rebuilds_on_miss(self):
        """Eviction-under-use: an Executable owns its GraphTensors, so LRU
        eviction of its store entry must not break serving; the next
        compile for that graph rebuilds on miss, visibly (built_ms_total
        counts rebuild churn)."""
        store = runtime.GraphStore(max_entries=1)
        ds_a = make_dataset("cora", seed=0, scale=0.05)
        ds_b = make_dataset("citeseer", seed=0, scale=0.05)
        kw = dict(backend="reference", max_shard_n=64, store=store, seed=0)
        spec_a = _spec("gcn", ds_a.profile)
        exe_a = runtime.compile(spec_a, ds_a, graph_key="a", **kw)
        ref = np.asarray(exe_a.forward())
        built_after_a = store.stats["built_ms_total"]
        assert built_after_a > 0

        # compiling for graph b evicts a's (sole-capacity) store entry
        runtime.compile(_spec("gcn", ds_b.profile), ds_b, graph_key="b",
                        **kw)
        assert store.stats["evictions"] == 1
        assert store.stats["built_ms_total"] > built_after_a

        # the evicted Executable keeps serving correctly, including a
        # full recompute of its cached softmax after invalidation
        classes, _ = exe_a.predict(np.array([0, 1, 2]))
        np.testing.assert_array_equal(classes,
                                      np.argmax(ref[:3], axis=-1))
        exe_a.invalidate()
        np.testing.assert_allclose(np.asarray(exe_a.forward()), ref,
                                   atol=1e-6, rtol=1e-6)

        # rebuild-on-miss: a fresh compile for graph a cannot hit
        misses0 = store.stats["misses"]
        built0 = store.stats["built_ms_total"]
        exe_a2 = runtime.compile(spec_a, ds_a, graph_key="a", **kw)
        assert store.stats["misses"] == misses0 + 1
        assert store.stats["built_ms_total"] > built0
        np.testing.assert_allclose(np.asarray(exe_a2.forward()), ref,
                                   atol=1e-5, rtol=1e-5)


class TestBackendParity:
    """Acceptance: compile(..., backend="reference") produces logits
    allclose to backend="pallas" for every zoo arch on the Table-II
    datasets (scaled down: pallas runs in interpret mode on CPU)."""

    @pytest.mark.parametrize("arch", ARCHS)
    @pytest.mark.parametrize("dataset", sorted(TABLE2_DATASETS))
    def test_reference_matches_pallas(self, arch, dataset):
        ds = make_dataset(dataset, seed=1, scale=SCALES[dataset])
        spec = _spec(arch, ds.profile)
        store = runtime.GraphStore()
        kw = dict(max_shard_n=16, store=store, graph_key=dataset, seed=0)
        ref_exe = runtime.compile(spec, ds, backend="reference", **kw)
        pal_exe = runtime.compile(spec, ds, backend="pallas", **kw)
        assert ref_exe.plan is pal_exe.plan     # content-hash memo shares
        np.testing.assert_allclose(
            np.asarray(pal_exe.forward()), np.asarray(ref_exe.forward()),
            atol=1e-4, rtol=1e-4)


class TestPlanCacheAndSerialization:
    def test_plan_json_roundtrip(self):
        prof = TABLE2_DATASETS["cora"]
        spec = ZooSpec("gat", prof.feature_dim, 16, prof.num_classes,
                       num_layers=3, heads=2)
        plan = executor.plan_model(spec, prof.num_nodes, prof.num_edges)
        blob = json.dumps(plan.to_json())
        back = executor.ModelPlan.from_json(json.loads(blob))
        assert back == plan
        assert back.layers[0].order == plan.layers[0].order
        assert back.shard_n == plan.shard_n

    def test_plan_model_content_hash_memo(self):
        executor.clear_plan_cache()
        prof = TABLE2_DATASETS["citeseer"]
        spec = ZooSpec("gcn", prof.feature_dim, 16, prof.num_classes)
        p1 = executor.plan_model(spec, prof.num_nodes, prof.num_edges)
        p2 = executor.plan_model(spec, prof.num_nodes, prof.num_edges)
        assert p1 is p2
        stats = executor.plan_cache_stats()
        assert stats["misses"] == 1 and stats["hits"] == 1
        # any input that shapes the plan is part of the key
        executor.plan_model(spec, prof.num_nodes, prof.num_edges, max_n=64)
        assert executor.plan_cache_stats()["misses"] == 2

    def test_plan_disk_cache_skips_replanning(self, tmp_path):
        executor.clear_plan_cache()
        prof = TABLE2_DATASETS["cora"]
        spec = ZooSpec("sage_mean", prof.feature_dim, 16, prof.num_classes)
        p1 = executor.plan_model(spec, prof.num_nodes, prof.num_edges,
                                 cache_dir=tmp_path)
        assert len(list(tmp_path.glob("*.json"))) == 1
        # a "restarted" process: fresh in-memory cache, same disk dir
        executor.clear_plan_cache()
        p2 = executor.plan_model(spec, prof.num_nodes, prof.num_edges,
                                 cache_dir=tmp_path)
        assert p2 == p1
        assert executor.plan_cache_stats()["disk_hits"] == 1
        assert executor.plan_cache_stats()["misses"] == 0


class TestDeprecationShims:
    def test_old_api_warns_and_matches(self):
        from repro.gnn.models import build_zoo_graph, zoo_forward
        ds = make_dataset("cora", seed=0, scale=0.05)
        exe = runtime.compile(_spec("gcn", ds.profile), ds,
                              backend="reference", max_shard_n=64)
        with pytest.warns(DeprecationWarning):
            gt = build_zoo_graph(ds.edges, ds.profile.num_nodes,
                                 exe.plan.shard_n, "gcn")
        with pytest.warns(DeprecationWarning):
            old = zoo_forward(exe.spec, exe.params, gt,
                              gt.group(jnp.asarray(ds.features)),
                              plans=exe.plan.layers)
        np.testing.assert_allclose(np.asarray(old), np.asarray(exe.forward()),
                                   atol=1e-5, rtol=1e-5)

    def test_new_consumers_emit_no_deprecation_warnings(self):
        ds = make_dataset("cora", seed=0, scale=0.05)
        from repro.serving.gnn_engine import GNNServeEngine, NodeRequest
        eng = GNNServeEngine(max_shard_n=64, backend="reference")
        eng.register_graph("cora", ds)
        eng.register_model("gcn", _spec("gcn", ds.profile))
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            eng.serve([NodeRequest("cora", np.array([0, 1]), model="gcn")])


class TestServingOnRuntime:
    def test_engine_caches_executables(self):
        ds = make_dataset("cora", seed=0, scale=0.05)
        from repro.serving.gnn_engine import GNNServeEngine, NodeRequest
        eng = GNNServeEngine(max_shard_n=64, backend="reference")
        eng.register_graph("cora", ds)
        eng.register_model("gcn", _spec("gcn", ds.profile))
        exe = eng.executable("gcn", "cora")
        assert isinstance(exe, runtime.Executable)
        eng.serve([NodeRequest("cora", np.array([0]), model="gcn")])
        assert eng.executable("gcn", "cora") is exe
        assert eng.stats["compiles"] == 1
        # weight swap drops the compiled unit
        eng.register_model("gcn", _spec("gcn", ds.profile), seed=5)
        assert eng.executable("gcn", "cora") is not exe


class TestNodeIdValidation:
    """Negative ids used to wrap around (numpy indexing) and return the
    WRONG node's prediction; ids >= N clamped/wrapped. Both must raise."""

    def _exe(self):
        ds = make_dataset("cora", seed=0, scale=0.05)
        return ds, runtime.compile(_spec("gcn", ds.profile), ds,
                                   backend="reference", max_shard_n=64)

    def test_predict_rejects_out_of_range_ids(self):
        ds, exe = self._exe()
        n = ds.profile.num_nodes
        with pytest.raises(ValueError, match="node ids"):
            exe.predict([-1])
        with pytest.raises(ValueError, match="node ids"):
            exe.predict([0, n])
        # valid boundary ids still work
        classes, probs = exe.predict([0, n - 1])
        assert classes.shape == (2,)

    def test_forward_nodes_rejects_out_of_range_ids(self):
        ds, exe = self._exe()
        with pytest.raises(ValueError, match="node ids"):
            exe.forward_nodes([-3])
        with pytest.raises(ValueError, match="node ids"):
            exe.forward_nodes([ds.profile.num_nodes + 7])

    def test_stale_ids_surface_as_typed_failed_outcome(self):
        """A request validated by route() against the profile at admission
        can still hit a smaller graph at step time (re-registration race);
        the Executable's ValueError must come back as a typed Failed for
        THAT request only — a valid request sharing the micro-batch still
        completes."""
        from repro.serving import Completed, Failed, SchedulerConfig, Server
        from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

        big = make_dataset("cora", seed=0, scale=0.05)
        small = make_dataset("cora", seed=0, scale=0.02)
        eng = GNNServeEngine(max_shard_n=64, backend="reference")
        eng.register_graph("cora", big)
        eng.register_model("gcn", _spec("gcn", big.profile))
        server = Server(eng, SchedulerConfig(max_batch_size=2))
        bad = server.submit(NodeRequest(
            "cora", np.array([big.profile.num_nodes - 1]), "gcn"))
        ok = server.submit(NodeRequest("cora", np.array([0]), "gcn"))
        # shrink the graph after admission, before dispatch: both requests
        # are already queued on the same (model, graph) stream
        eng.register_graph("cora", small)
        server.drain()
        out = bad.result()
        assert isinstance(out, Failed)
        assert "node ids" in out.error
        # the co-batched valid request is NOT poisoned by its neighbor
        assert isinstance(ok.result(), Completed)
        m = server.metrics()
        assert m["failed"] == 1 and m["completed"] == 1


class TestParamSerializationRobustness:
    def test_unflatten_handles_non_contiguous_digit_keys(self):
        from repro.runtime.executable import (_flatten_params,
                                              _unflatten_params)
        tree = {"layers": [{"w": np.ones((2, 2))},
                           {"w": np.full((2, 2), 2.0)},
                           {"w": np.full((2, 2), 3.0)}]}
        flat = _flatten_params(tree)
        # prune the middle layer, as a pruned/partial checkpoint would
        pruned = {k: v for k, v in flat.items() if "/1/" not in k}
        rebuilt = _unflatten_params(pruned)
        assert len(rebuilt["layers"]) == 2
        np.testing.assert_array_equal(np.asarray(rebuilt["layers"][0]["w"]),
                                      flat["layers/0/w"])
        np.testing.assert_array_equal(np.asarray(rebuilt["layers"][1]["w"]),
                                      flat["layers/2/w"])

    def test_load_params_roundtrip_with_pruned_checkpoint(self, tmp_path):
        ds = make_dataset("cora", seed=0, scale=0.05)
        spec = _spec("gcn", ds.profile)
        exe = runtime.compile(spec, ds, backend="reference", max_shard_n=64)
        path = tmp_path / "params.npz"
        exe.save_params(path)
        # rewrite the archive with a gap in the layer indices: layer 1
        # saved under index 3 (a partial export / manual surgery case)
        with np.load(path) as z:
            flat = {k.replace("layers/1/", "layers/3/"): z[k] for k in z}
        np.savez(path, **flat)
        loaded = exe.load_params(path)     # must not KeyError
        assert len(loaded["layers"]) == len(spec.layer_dims)
        logits = exe.forward()             # still runs end to end
        assert logits.shape == (ds.profile.num_nodes, ds.profile.num_classes)

"""repro.gnn subsystem: model zoo vs pure-jnp references, executor budget
invariants, and the batched serving engine's caching behavior."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.gnn.executor import plan_model
from repro.gnn.models import (ARCHS, ZooSpec, build_zoo_graph, init_zoo,
                              zoo_forward)
from repro.graphs.datasets import DATASETS, load, make_dataset
from repro.kernels import ref
from repro.serving.gnn_engine import GNNServeEngine, NodeRequest


@pytest.fixture(autouse=True)
def _ref_backend(monkeypatch):
    """Model-level tests target assembly logic (grouping, normalization,
    attention, planning), not kernel numerics — kernel parity is covered by
    tests/test_kernels.py. The jnp backend keeps the sweep fast on CPU."""
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")


def _flat_adj(gt) -> np.ndarray:
    b = np.asarray(gt.blocks)
    s, _, n, _ = b.shape
    return b.transpose(0, 2, 1, 3).reshape(s * n, s * n)


def _ref_forward(arch, layers, a, h):
    n_layers = len(layers)
    for i, L in enumerate(layers):
        act = "relu" if i < n_layers - 1 else "none"
        if arch == "gcn":
            h = ref.gcn_layer(a, h, L["w"], activation=act)
        elif arch == "sage_mean":
            h = ref.sage_mean_layer(a, h, L["w"], activation=act)
        elif arch == "sage_max":
            h = ref.sage_max_pool_layer(a, h, L["w_pool"], L["b_pool"],
                                        L["w"], activation=act)
        elif arch == "gin":
            h = ref.gin_layer(a, h, L["eps"], L["w1"], L["b1"], L["w2"],
                              L["b2"], activation=act)
        elif arch == "gat":
            h = ref.gat_layer(a, h, L["w"], L["a_src"], L["a_dst"],
                              activation=act)
    return h


class TestZooVsReference:
    """Every zoo model through the engine path must match the flat pure-jnp
    oracle on (scaled) Cora/Citeseer profiles within fp32 tolerance —
    including multi-shard grids (max_n forces S > 1)."""

    @pytest.mark.parametrize("arch", ARCHS)
    @pytest.mark.parametrize("dataset", ["cora", "citeseer"])
    def test_model_matches_reference(self, arch, dataset):
        ds = make_dataset(dataset, seed=1, scale=0.08)
        prof = ds.profile
        spec = ZooSpec(arch, prof.feature_dim, 8, prof.num_classes,
                       num_layers=2, heads=2)
        mp = plan_model(spec, prof.num_nodes, ds.edges.shape[0], max_n=64)
        assert mp.layers[0].S > 1, "test must exercise a multi-shard grid"
        gt = build_zoo_graph(ds.edges, prof.num_nodes, mp.shard_n, arch)
        params = init_zoo(jax.random.key(0), spec)
        out = zoo_forward(spec, params, gt, gt.group(jnp.asarray(ds.features)),
                          plans=mp.layers)

        a = _flat_adj(gt)
        h = np.zeros((a.shape[0], prof.feature_dim), np.float32)
        h[:prof.num_nodes] = ds.features
        exp = np.asarray(_ref_forward(arch, params["layers"], a,
                                      jnp.asarray(h)))[:prof.num_nodes]
        np.testing.assert_allclose(np.asarray(out), exp,
                                   atol=5e-5, rtol=5e-5)

    def test_three_layer_gcn(self):
        ds = make_dataset("cora", seed=2, scale=0.05)
        prof = ds.profile
        spec = ZooSpec("gcn", prof.feature_dim, 8, prof.num_classes,
                       num_layers=3)
        mp = plan_model(spec, prof.num_nodes, ds.edges.shape[0], max_n=32)
        gt = build_zoo_graph(ds.edges, prof.num_nodes, mp.shard_n, "gcn")
        params = init_zoo(jax.random.key(1), spec)
        out = zoo_forward(spec, params, gt, gt.group(jnp.asarray(ds.features)),
                          plans=mp.layers)
        a = _flat_adj(gt)
        h = np.zeros((a.shape[0], prof.feature_dim), np.float32)
        h[:prof.num_nodes] = ds.features
        exp = np.asarray(_ref_forward("gcn", params["layers"], a,
                                      jnp.asarray(h)))[:prof.num_nodes]
        np.testing.assert_allclose(np.asarray(out), exp, atol=5e-5, rtol=5e-5)

    def test_pallas_interpret_parity(self, monkeypatch):
        """One small end-to-end run through the real kernel path (interpret
        mode on CPU) to pin the engine wiring, not just the ref backend."""
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
        r = np.random.default_rng(0)
        n_nodes, d, c = 40, 16, 4
        e = r.integers(0, n_nodes, (160, 2))
        e = e[e[:, 0] != e[:, 1]]
        feats = r.standard_normal((n_nodes, d)).astype(np.float32)
        for arch in ("gcn", "gat"):
            spec = ZooSpec(arch, d, 8, c, num_layers=2, heads=2)
            mp = plan_model(spec, n_nodes, len(e), max_n=16)
            gt = build_zoo_graph(e, n_nodes, mp.shard_n, arch)
            params = init_zoo(jax.random.key(0), spec)
            out = zoo_forward(spec, params, gt, gt.group(jnp.asarray(feats)),
                              plans=mp.layers)
            a = _flat_adj(gt)
            h = np.zeros((a.shape[0], d), np.float32)
            h[:n_nodes] = feats
            exp = np.asarray(_ref_forward(arch, params["layers"], a,
                                          jnp.asarray(h)))[:n_nodes]
            np.testing.assert_allclose(np.asarray(out), exp,
                                       atol=1e-4, rtol=1e-4)


def test_load_helper_matches_make_dataset():
    """load() is the one-call (features, labels, edges) contract."""
    f, y, e = load("cora", seed=3, scale=0.05)
    ds = make_dataset("cora", seed=3, scale=0.05)
    np.testing.assert_array_equal(f, ds.features)
    np.testing.assert_array_equal(y, ds.labels)
    np.testing.assert_array_equal(e, ds.edges)
    assert f.shape[0] == y.shape[0] == ds.profile.num_nodes
    assert e.ndim == 2 and e.shape[1] == 2


class TestExecutor:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_plans_fit_onchip_budget(self, arch):
        """Planner invariant: src block + dst accumulators + adjacency
        block, double-buffered, never exceed the platform budget."""
        prof = DATASETS["cora"]
        spec = ZooSpec(arch, prof.feature_dim, 16, prof.num_classes,
                       num_layers=3, heads=2)
        mp = plan_model(spec, prof.num_nodes, prof.num_edges)
        assert len(mp.layers) == 3
        for p in mp.layers:
            assert p.onchip_bytes_used() <= mp.onchip_bytes // 2
            assert 1 <= p.B <= p.d_agg
            assert p.S == -(-mp.num_nodes // p.n)
            assert p.est_layer_s > 0
        # the single execution shard size keeps EVERY layer under budget
        for p in mp.layers:
            used = (2 * mp.shard_n * p.B + mp.shard_n ** 2) * 4
            assert used <= mp.onchip_bytes // 2

    def test_blocking_chosen_for_wide_features(self):
        """Cora's 1433-dim input layer must be dimension-blocked (B < D):
        the whole point of the paper's dataflow."""
        prof = DATASETS["cora"]
        spec = ZooSpec("gcn", prof.feature_dim, 16, prof.num_classes)
        mp = plan_model(spec, prof.num_nodes, prof.num_edges)
        assert mp.layers[0].B < prof.feature_dim

    def test_only_gcn_fuses(self):
        prof = DATASETS["citeseer"]
        for arch in ARCHS:
            spec = ZooSpec(arch, prof.feature_dim, 16, prof.num_classes,
                           heads=2)
            mp = plan_model(spec, prof.num_nodes, prof.num_edges)
            if arch != "gcn":
                assert not any(p.fused for p in mp.layers)

    def test_summary_renders(self):
        prof = DATASETS["cora"]
        spec = ZooSpec("gcn", prof.feature_dim, 16, prof.num_classes)
        mp = plan_model(spec, prof.num_nodes, prof.num_edges)
        s = mp.summary()
        assert "gcn" in s and "fused" in s


class TestGNNServing:
    def _engine(self, archs=("gcn", "gat")):
        eng = GNNServeEngine(max_shard_n=128)
        ds = make_dataset("cora", seed=0, scale=0.08)
        eng.register_graph("cora", ds)
        for a in archs:
            eng.register_model(a, ZooSpec(a, ds.profile.feature_dim, 8,
                                          ds.profile.num_classes,
                                          num_layers=2, heads=2))
        return eng, ds

    def test_predictions_match_direct_forward(self):
        eng, ds = self._engine(archs=("gcn",))
        ids = np.array([0, 3, 17, 40])
        [pred] = eng.serve([NodeRequest("cora", ids, model="gcn")])
        spec = eng._models["gcn"].spec
        params = eng._models["gcn"].params
        mp = eng.model_plan("gcn", "cora")
        gt = build_zoo_graph(ds.edges, ds.profile.num_nodes, mp.shard_n,
                             "gcn")
        logits = zoo_forward(spec, params, gt,
                             gt.group(jnp.asarray(ds.features)),
                             plans=mp.layers)
        np.testing.assert_array_equal(
            pred.classes, np.argmax(np.asarray(logits)[ids], axis=-1))
        assert pred.probs.shape == (4,)
        assert np.all((pred.probs > 0) & (pred.probs <= 1))

    def test_cache_hits_and_batching(self):
        eng, ds = self._engine()
        n = ds.profile.num_nodes
        reqs = [NodeRequest("cora", np.array([i % n, (i * 7) % n]),
                            model=("gcn" if i % 2 else "gat"))
                for i in range(10)]
        for r in reqs:
            eng.submit(r)
        preds = eng.flush()
        assert len(preds) == 10
        # answers come back in request order with the right routing
        for r, p in zip(reqs, preds):
            assert p.model == r.model and p.graph == r.graph
            np.testing.assert_array_equal(p.node_ids, r.node_ids)
        s = eng.stats
        # 2 (model, graph) pairs -> 2 logits misses, everything else hits
        assert s["logits_cache_misses"] == 2
        assert s["logits_cache_hits"] == 8
        assert s["batches"] == 2
        # second flush of the same traffic is all cache hits
        preds2 = eng.serve(reqs)
        assert eng.stats["logits_cache_misses"] == 2
        np.testing.assert_array_equal(preds2[0].classes, preds[0].classes)

    def test_per_request_latency_attribution(self):
        """Regression: a two-request (model, graph) group must NOT report
        the whole group's wall time (compile included) for every request —
        the cold full-graph forward is charged to the request that
        triggered it, the second pays only its gather, and compile time
        stays out of request latency entirely."""
        eng, ds = self._engine(archs=("gcn",))
        [p1, p2] = eng.serve([
            NodeRequest("cora", np.array([0, 1]), model="gcn"),
            NodeRequest("cora", np.array([2, 3]), model="gcn")])
        assert p1.engine_ms > 0 and p2.engine_ms > 0
        # the full-graph forward dominates a pure gather by orders of
        # magnitude; identical values would mean group-wall misattribution
        assert p2.engine_ms < p1.engine_ms
        # no queueing in the sync path; latency_ms = queue_ms + engine_ms
        assert p1.queue_ms == 0.0 and p2.queue_ms == 0.0
        assert p1.latency_ms == pytest.approx(p1.engine_ms)
        # compile time accrues to engine stats, not to any request
        assert eng.stats["compile_ms_total"] > 0

    def test_graph_cache_shared_by_signature(self):
        """gat and sage_max both need ('sum', self-loops) GraphTensors:
        one build serves both (GNNIE-style graph-specific caching)."""
        eng, ds = self._engine(archs=("gat", "sage_max"))
        eng.serve([NodeRequest("cora", np.array([1]), model="gat"),
                   NodeRequest("cora", np.array([2]), model="sage_max")])
        assert eng.stats["graph_cache_misses"] == 1
        assert eng.stats["graph_cache_hits"] == 1

    def test_invalidate_on_model_update(self):
        eng, ds = self._engine(archs=("gcn",))
        [p1] = eng.serve([NodeRequest("cora", np.array([5]), model="gcn")])
        miss0 = eng.stats["logits_cache_misses"]
        # re-registering (weight swap) must drop the stale logits
        eng.register_model("gcn", eng._models["gcn"].spec, seed=9)
        [p2] = eng.serve([NodeRequest("cora", np.array([5]), model="gcn")])
        assert eng.stats["logits_cache_misses"] == miss0 + 1

    def test_unknown_names_and_bad_ids_raise(self):
        eng, ds = self._engine(archs=("gcn",))
        with pytest.raises(KeyError):
            eng.serve([NodeRequest("nope", np.array([0]), model="gcn")])
        with pytest.raises(KeyError):
            eng.serve([NodeRequest("cora", np.array([0]), model="nope")])
        with pytest.raises(IndexError):
            eng.serve([NodeRequest("cora", np.array([10 ** 9]),
                                   model="gcn")])

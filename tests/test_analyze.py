"""repro.analyze: every lint pass must catch its known-bad fixture, the
clean repo must produce zero findings, and the integration hooks
(runtime.compile(analyze=...), Server.start(analyze=...), the autotuner's
static pruning, the launch.analyze CLI) must gate on the report."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.analyze import (AnalysisError, Finding, Report, analyze_executable,
                           ast_lint, hlo_lint, jaxpr_lint, plan_lint,
                           preflight, severity_rank)
from repro.dist.hlo_analysis import CollectiveStats
from repro.gnn.executor import plan_model
from repro.gnn.models import ARCHS, ZooSpec
from repro.graphs.datasets import make_dataset


def _setup(scale=0.05, arch="gcn", hidden=8):
    ds = make_dataset("cora", seed=0, scale=scale)
    spec = ZooSpec(arch, ds.profile.feature_dim, hidden,
                   ds.profile.num_classes, num_layers=2)
    return ds, spec


@pytest.fixture(scope="module")
def tiny():
    """One compiled reference-backend gcn on scaled cora, shared by the
    read-only tests (tests that drive jit caches compile their own)."""
    ds, spec = _setup()
    exe = runtime.compile(spec, ds, backend="reference", max_shard_n=64)
    return ds, spec, exe


# --------------------------------------------------------------------------
# report machinery
# --------------------------------------------------------------------------

def _finding(rule="XX001", severity="error", pass_name="plan",
             message="boom", location="here"):
    return Finding(rule=rule, severity=severity, pass_name=pass_name,
                   message=message, location=location)


def test_severity_rank_orders_and_validates():
    assert severity_rank("info") < severity_rank("warning") \
        < severity_rank("error")
    with pytest.raises(ValueError, match="unknown severity"):
        severity_rank("fatal")
    with pytest.raises(ValueError):
        _finding(severity="fatal")   # Finding validates eagerly


def test_report_thresholds_render_and_json_roundtrip():
    rep = Report()
    rep.add(_finding(severity="info"), _finding(severity="warning"))
    assert not rep.failed("error") and rep.failed("warning")
    assert rep.failed("info") and not rep.failed("never")
    assert rep.worst() == "warning"

    rep.add(_finding(severity="error", rule="PL001"))
    assert rep.failed("error") and rep.worst() == "error"
    assert rep.count("error") == 1

    text = rep.render()
    assert "PL001" in text and "1 error" in text
    doc = rep.to_json()
    assert doc["counts"] == {"info": 1, "warning": 1, "error": 1}
    back = [Finding.from_json(d) for d in doc["findings"]]
    assert back == rep.findings


def test_analysis_error_carries_report():
    rep = Report(findings=[_finding(rule="CC001")])
    err = AnalysisError(rep)
    assert err.report is rep and "CC001" in str(err)


# --------------------------------------------------------------------------
# host-sync AST lint
# --------------------------------------------------------------------------

_HOT_FIXTURE = """\
import jax
import jax.numpy as jnp
import numpy as np

def serve(x):
    a = x.item()
    jax.block_until_ready(x)
    b = float(jnp.max(x))
    c = jax.device_get(x)
    d = np.asarray(jnp.sum(x))
    for _ in range(3):
        fn = jax.jit(lambda y: y)
    return a, b, c, d, fn
"""


def test_host_sync_fixture_fires_every_rule():
    fs = ast_lint.lint_source(_HOT_FIXTURE, "fixture.py")
    by_rule = {}
    for f in fs:
        by_rule.setdefault(f.rule, []).append(f)
    assert set(by_rule) == {"HS001", "HS002", "HS003", "HS004", "RT101"}
    assert len(by_rule["HS004"]) == 2          # device_get + np.asarray
    assert by_rule["HS001"][0].severity == "error"
    assert by_rule["HS003"][0].severity == "warning"
    # jit-in-loop is a retrace finding that happens to live in the AST pass
    assert by_rule["RT101"][0].pass_name == "retrace"
    assert all(f.location.startswith("fixture.py:") for f in fs)


def test_host_sync_metadata_accessors_not_flagged():
    src = ("import jax.numpy as jnp\n"
           "def f():\n"
           "    lo = float(jnp.finfo(jnp.float32).max)\n"
           "    hi = int(jnp.iinfo(jnp.int32).max)\n"
           "    return lo, hi\n")
    assert ast_lint.lint_source(src) == []


def test_host_sync_suppression_by_rule_and_pass():
    src = ("import jax\n"
           "def f(x):\n"
           "    a = x.item()  # analyze: allow(HS001)\n"
           "    b = jax.device_get(x)  # analyze: allow(host-sync)\n"
           "    return a, b\n")
    assert ast_lint.lint_source(src) == []
    # a different rule's token does NOT suppress
    src2 = "def f(x):\n    return x.item()  # analyze: allow(HS002)\n"
    assert [f.rule for f in ast_lint.lint_source(src2)] == ["HS001"]


def test_host_sync_syntax_error_is_a_finding_not_a_crash():
    fs = ast_lint.lint_source("def broken(:\n", "bad.py")
    assert [f.rule for f in fs] == ["HS000"]
    assert fs[0].severity == "error"


def test_hot_paths_are_clean():
    """The shipped serving/runtime/kernels trees carry zero host-sync
    findings — the PR's acceptance gate for the AST pass."""
    assert ast_lint.lint_hot_paths() == []


# --------------------------------------------------------------------------
# retrace pass
# --------------------------------------------------------------------------

def test_python_scalar_leaves_flagged():
    fs = jaxpr_lint.python_scalar_leaves(
        {"w": jnp.ones(3), "eps": 0.5, "flag": True}, name="params")
    assert [f.rule for f in fs] == ["RT002", "RT002"]
    # numpy scalars are typed — not flagged
    assert jaxpr_lint.python_scalar_leaves(
        {"eps": np.float32(0.5)}, name="p") == []


def test_trace_stability_oracle():
    grows = jax.jit(lambda x: x + 1)
    fs = jaxpr_lint.trace_stability(
        grows, [(jnp.ones(i),) for i in (1, 2, 3)], name="grows")
    assert [f.rule for f in fs] == ["RT003"]
    assert fs[0].severity == "error"

    stable = jax.jit(lambda x: x * 2)
    assert jaxpr_lint.trace_stability(
        stable, [(jnp.ones(4),)] * 3, name="stable") == []

    # a plain callable exposes no cache: explicit skip, not silence
    fs = jaxpr_lint.trace_stability(lambda x: x, [], name="plain")
    assert [f.rule for f in fs] == ["RT000"]


def test_forward_nodes_bucket_shares_traces(tiny):
    """Regression for the per-node-batch recompile: every batch size in
    one pad bucket must reuse one gather trace (and still gather the
    right rows)."""
    ds, _spec, _ = tiny
    _, spec = _setup()
    exe = runtime.compile(spec, ds, backend="reference", max_shard_n=64)
    logits = np.asarray(exe.forward())
    n = ds.profile.num_nodes
    for k in (1, 2, 3, 5, 8):
        ids = np.arange(k) % n
        np.testing.assert_allclose(np.asarray(exe.forward_nodes(ids)),
                                   logits[ids], rtol=1e-5, atol=1e-6)
    assert jaxpr_lint.cache_size(exe._jit_gather) == 1
    exe.forward_nodes(np.arange(9) % n)       # next bucket: one new trace
    assert jaxpr_lint.cache_size(exe._jit_gather) == 2
    assert exe.forward_nodes(np.arange(0)).shape[0] == 0


# --------------------------------------------------------------------------
# dtype pass
# --------------------------------------------------------------------------

def test_dtype_f64_promotion_flagged():
    from jax.experimental import enable_x64
    with enable_x64():
        closed = jax.make_jaxpr(lambda x: jnp.sin(x) * 2.0)(
            jnp.ones(3, jnp.float64))
    fs = jaxpr_lint.dtype_findings(closed, name="fix")
    assert "DT001" in {f.rule for f in fs}
    assert jaxpr_lint.dtype_findings(closed, name="fix",
                                     allow_f64=True) == []


def test_dtype_weak_typed_entry_flagged():
    closed = jax.make_jaxpr(lambda x: x + 1)(3.0)   # Python scalar arg
    fs = jaxpr_lint.dtype_findings(closed, name="fix")
    assert [f.rule for f in fs if f.rule == "DT002"] == ["DT002"]


def test_dtype_int32_overflow_scale_flagged():
    big = jax.ShapeDtypeStruct((2 ** 16, 2 ** 16), jnp.float32)
    closed = jax.make_jaxpr(lambda x: x + 1)(big)   # 2^32 elements, no mem
    fs = jaxpr_lint.dtype_findings(closed, name="fix")
    assert "DT003" in {f.rule for f in fs}


# --------------------------------------------------------------------------
# plan-legality pass
# --------------------------------------------------------------------------

def _plan(ds, arch="gcn", hidden=8, max_n=64):
    spec = ZooSpec(arch, ds.profile.feature_dim, hidden,
                   ds.profile.num_classes, num_layers=2)
    return plan_model(spec, ds.profile.num_nodes, ds.edges.shape[0],
                      max_n=max_n)


def _with_layer(plan, layer):
    return dataclasses.replace(plan, layers=(layer,) + plan.layers[1:])


def test_analytic_plans_clean_every_arch(tiny):
    ds, _, _ = tiny
    for arch in ARCHS:
        plan = _plan(ds, arch)
        for backend in (None, "reference", "pallas"):
            assert plan_lint.check_model_plan(
                plan, backend_name=backend) == [], arch


def test_plan_fixtures_fire_each_rule(tiny):
    ds, _, _ = tiny
    plan = _plan(ds)
    lp = plan.layers[0]

    def rules(p, backend=None):
        return {f.rule for f in plan_lint.check_model_plan(
            p, backend_name=backend)}

    assert "PL001" in rules(_with_layer(
        plan, dataclasses.replace(lp, B=lp.d_agg + 5)))
    assert "PL001" in rules(_with_layer(plan, dataclasses.replace(lp, B=0)))
    assert "PL002" in rules(_with_layer(plan, dataclasses.replace(
        lp, S=lp.S + 3)))
    assert "PL005" in rules(_with_layer(plan, dataclasses.replace(
        lp, order="zigzag")))
    # fused demands linear aggregation: legal on gcn, an error on gin
    fused = _with_layer(plan, dataclasses.replace(lp, fused=True))
    assert rules(fused) == set()
    assert "PL004" in rules(dataclasses.replace(fused, arch="gin"))
    # a fused n=2048 working set (~38 MiB) blows the 16 MiB pallas VMEM
    huge = dataclasses.replace(
        lp, n=2048, S=-(-plan.num_nodes // 2048), B=lp.d_agg, fused=True)
    assert "PL003" in rules(_with_layer(plan, huge), backend="pallas")
    # reddit-scale activation grid: int32 flattened indexing wraps
    wide = dataclasses.replace(lp, d_agg=2 ** 31 // (lp.S * lp.n) + 1)
    assert "PL006" in rules(_with_layer(plan, wide))


def test_executed_digest_ignores_analytic_metadata(tiny):
    ds, _, _ = tiny
    plan = _plan(ds)
    lp = plan.layers[0]
    flipped = _with_layer(plan, dataclasses.replace(
        lp, order="src_stationary" if lp.order == "dst_stationary"
        else "dst_stationary"))
    assert plan_lint.executed_digest(flipped) == \
        plan_lint.executed_digest(plan)
    rebocked = _with_layer(plan, dataclasses.replace(lp, B=max(1, lp.B // 2)))
    assert plan_lint.executed_digest(rebocked) != \
        plan_lint.executed_digest(plan)


def test_prune_keeps_analytic_drops_illegal_and_duplicates(tiny):
    ds, _, _ = tiny
    plan = _plan(ds)
    lp = plan.layers[0]
    order_dup = _with_layer(plan, dataclasses.replace(
        lp, order="src_stationary" if lp.order == "dst_stationary"
        else "dst_stationary"))
    illegal = _with_layer(plan, dataclasses.replace(lp, B=0))
    distinct = _with_layer(plan, dataclasses.replace(lp, B=max(1, lp.B // 2)))

    kept, pruned = plan_lint.prune_candidates(
        [plan, order_dup, illegal, distinct])
    assert kept == [plan, distinct]
    assert [(p["index"], p["reason"]) for p in pruned] == \
        [(1, "duplicate-execution"), (2, "illegal")]
    assert pruned[1]["rules"] == ["PL001"]

    # candidate #0 is the analytic fallback: never pruned, even illegal
    kept, pruned = plan_lint.prune_candidates([illegal, plan])
    assert kept[0] is illegal and not any(p["index"] == 0 for p in pruned)


# --------------------------------------------------------------------------
# comm-contract pass
# --------------------------------------------------------------------------

def _stats(ag_bytes, extra_kind=None):
    wire = {"all-gather": ag_bytes, "all-reduce": 64.0}
    counts = {"all-gather": 2, "all-reduce": 2}
    if extra_kind:
        wire[extra_kind] = 512.0
        counts[extra_kind] = 1
    return CollectiveStats(operand_bytes={}, wire_bytes=wire, counts=counts)


def test_comm_contract_fixtures():
    ok = hlo_lint.check_comm_contract(
        _stats(1000.0), expected_allgather_bytes=1000.0,
        plan_allgather_bytes=1000.0)
    assert ok == []

    meas = hlo_lint.check_comm_contract(
        _stats(1500.0), expected_allgather_bytes=1000.0)
    assert [f.rule for f in meas] == ["CC001"]
    assert meas[0].severity == "error"

    drift = hlo_lint.check_comm_contract(
        _stats(1000.0), expected_allgather_bytes=1000.0,
        plan_allgather_bytes=1200.0)
    assert [f.rule for f in drift] == ["CC002"]

    extra = hlo_lint.check_comm_contract(
        _stats(1000.0, extra_kind="all-to-all"),
        expected_allgather_bytes=1000.0)
    assert [f.rule for f in extra] == ["CC003"]
    assert extra[0].severity == "warning"

    vac = hlo_lint.check_comm_contract(
        CollectiveStats(operand_bytes={}, wire_bytes={}, counts={}),
        expected_allgather_bytes=0.0)
    assert [(f.rule, f.severity) for f in vac] == [("CC004", "info")]


def test_comm_contract_over_comm_stats_dict():
    cs = {"measured_wire_bytes": {"all-gather": 2000.0},
          "measured_counts": {"all-gather": 2},
          "expected_allgather_wire_bytes": 1000.0,
          "plan_allgather_bytes_per_layer": {"0": 600.0, "1": 400.0}}
    fs = hlo_lint.check_comm_stats(cs, location="fixture")
    assert [f.rule for f in fs] == ["CC001"]
    cs["measured_wire_bytes"]["all-gather"] = 1000.0
    assert hlo_lint.check_comm_stats(cs) == []


# --------------------------------------------------------------------------
# integration hooks
# --------------------------------------------------------------------------

def test_analyze_executable_clean_with_probe(tiny):
    ds, _, _ = tiny
    _, spec = _setup()
    exe = runtime.compile(spec, ds, backend="reference", max_shard_n=64)
    rep = analyze_executable(exe, probe=True)
    assert rep.findings == []
    assert "comm" in rep.skipped and "host-sync" in rep.skipped
    assert set(rep.timings_ms) == {"retrace+dtype", "plan"}


def test_compile_analyze_modes(tiny):
    ds, spec, _ = tiny
    with pytest.raises(ValueError, match="analyze"):
        runtime.compile(spec, ds, backend="reference", max_shard_n=64,
                        analyze="loud")
    exe = runtime.compile(spec, ds, backend="reference", max_shard_n=64,
                          analyze="error")
    assert exe.analysis is not None and exe.analysis.findings == []
    off = runtime.compile(spec, ds, backend="reference", max_shard_n=64,
                          analyze="off")
    assert off.analysis is None


def test_compile_analyze_error_raises(tiny, monkeypatch):
    ds, spec, _ = tiny
    import repro.analyze as analyze_mod
    bad = Report(findings=[_finding(rule="PL001")])
    monkeypatch.setattr(analyze_mod, "analyze_executable",
                        lambda exe, **kw: bad)
    with pytest.raises(AnalysisError) as err:
        runtime.compile(spec, ds, backend="reference", max_shard_n=64,
                        analyze="error")
    assert err.value.report is bad
    # "warn" downgrades the same report to a UserWarning
    with pytest.warns(UserWarning, match="PL001"):
        exe = runtime.compile(spec, ds, backend="reference", max_shard_n=64,
                              analyze="warn")
    assert exe.analysis is bad


def test_preflight_without_engine_is_hot_path_lint_only():
    rep = preflight()
    assert rep.findings == []
    assert "host-sync" in rep.timings_ms


def test_server_start_analyze_gate(monkeypatch):
    from repro.serving import SchedulerConfig, Server
    from repro.serving.gnn_engine import GNNServeEngine

    ds, spec = _setup()
    engine = GNNServeEngine(backend="reference")
    engine.register_graph("cora", ds)
    engine.register_model("gcn", spec, seed=0)
    srv = Server(engine, SchedulerConfig(max_batch_size=2))

    with pytest.raises(ValueError, match="analyze"):
        srv.start(analyze="bogus")
    assert srv._thread is None

    import repro.analyze as analyze_mod
    bad = Report(findings=[_finding(rule="HS001", pass_name="host-sync")])
    monkeypatch.setattr(analyze_mod, "preflight", lambda eng, **kw: bad)
    with pytest.raises(AnalysisError):
        srv.start(analyze="error")
    assert srv._thread is None          # refused before the driver spawned

    monkeypatch.undo()
    srv.start(analyze="error")          # clean repo: preflight passes
    try:
        assert srv._thread is not None
    finally:
        srv.stop()


def test_cli_gate_clean_on_this_checkout(capsys):
    """`python -m repro.launch.analyze --fail-on error` is the CI gate:
    it must exit 0 on the shipped tree (probes disabled keeps it fast)."""
    from repro.launch import analyze as cli
    rc = cli.main(["--fail-on", "error", "--no-probe"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 error" in out
    rc = cli.main(["--fail-on", "never", "--no-probe", "--json"])
    assert rc == 0

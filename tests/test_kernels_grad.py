"""Gradients through the Pallas ops: the custom_vjp (oracle-derived
backward) must match differentiating the pure-jnp reference directly."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _check(fn_op, fn_ref, *args, atol=1e-3):
    g_op = jax.grad(lambda *a: jnp.sum(jnp.square(fn_op(*a))), argnums=tuple(
        range(len(args))))(*args)
    g_ref = jax.grad(lambda *a: jnp.sum(jnp.square(fn_ref(*a))), argnums=tuple(
        range(len(args))))(*args)
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=atol, rtol=atol)


def test_dense_matmul_grad():
    x = jnp.asarray(RNG.standard_normal((24, 16)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((16, 8)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((8,)), jnp.float32)
    _check(lambda x, w, b: ops.dense_matmul(x, w, b, activation="relu",
                                            bm=8, bn=8, bk=8),
           lambda x, w, b: ref.dense_engine(x, w, b, activation="relu"),
           x, w, b)


def test_shard_spmm_grad():
    a = jnp.asarray((RNG.random((2, 2, 8, 8)) < 0.3), jnp.float32)
    h = jnp.asarray(RNG.standard_normal((2, 8, 16)), jnp.float32)
    _check(lambda a, h: ops.graph_aggregate(a, h, block_b=8),
           ref.shard_spmm, a, h)


def test_fused_gnn_grad():
    a = jnp.asarray((RNG.random((2, 2, 8, 8)) < 0.3), jnp.float32)
    h = jnp.asarray(RNG.standard_normal((2, 8, 16)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((16, 4)), jnp.float32)
    _check(lambda a, h, w: ops.fused_aggregate_extract(a, h, w,
                                                       activation="relu",
                                                       block_b=8),
           lambda a, h, w: ref.fused_gnn(a, h, w, activation="relu"),
           a, h, w)


def test_gather_aggregate_max_grad():
    s, n, e, d = 2, 8, 12, 16
    es = jnp.asarray(RNG.integers(0, n, (s, s, e)), jnp.int32)
    ed = jnp.asarray(RNG.integers(0, n, (s, s, e)), jnp.int32)
    ev = jnp.asarray(RNG.random((s, s, e)) < 0.6)
    h = jnp.asarray(RNG.standard_normal((s, n, d)), jnp.float32)

    def op_fn(h):
        return ops.gather_aggregate(es, ed, ev, h, op="max", block_b=8)

    g = jax.grad(lambda h: jnp.sum(jnp.square(op_fn(h))))(h)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.max(jnp.abs(g))) > 0


def test_flash_attention_grad():
    q = jnp.asarray(RNG.standard_normal((1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 32, 16)), jnp.float32)
    _check(lambda q, k, v: ops.attention(q, k, v, causal=True, bq=16, bk=16),
           lambda q, k, v: ref.flash_attention(q, k, v, causal=True),
           q, k, v)


def test_gnn_end_to_end_training_step():
    """A GCN training step through the Pallas kernels must move params."""
    from repro.core.models import (build_graph_tensors, init_gnn,
                                   make_forward, paper_spec)
    edges = RNG.integers(0, 40, (150, 2))
    feats = jnp.asarray(RNG.standard_normal((40, 12)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, 4, 40), jnp.int32)
    gt = build_graph_tensors(edges, 40, n=16, kind="gcn")
    spec = paper_spec("gcn", 12, 4)
    params = init_gnn(jax.random.key(0), spec)
    fwd = make_forward(spec)
    hg = gt.group(feats)

    def loss(p):
        logits = fwd(p, gt, hg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    l0, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert gn > 0

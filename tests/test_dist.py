"""Distribution layer: sharding rules (divisibility guards, axis-reuse
guards), HLO collective parsing, mesh construction purity."""
import pytest
import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

import importlib.util

if importlib.util.find_spec("repro.dist") is None:
    # skip only when the package is genuinely absent; a broken import
    # inside an existing repro.dist must still fail loudly
    pytest.skip("repro.dist not present in this build",
                allow_module_level=True)
from repro.dist import compat

if compat.AbstractMesh is None:
    # pre-AbstractMesh jax: keep the old graceful module-level skip
    pytest.skip("jax too old for AbstractMesh", allow_module_level=True)
abstract_mesh = compat.abstract_mesh
from repro.dist.hlo_analysis import analyze_collectives, type_bytes
from repro.dist.shardings import ShardingRules
from repro.nn.layers import Axes


def _mesh(shape=(16, 16), axes=("data", "model")):
    # dist.compat builds the AbstractMesh on both jax 0.4.x (no AxisType)
    # and jax >= 0.5 (axis_types required by newer constructors)
    return abstract_mesh(shape, axes)


class TestShardingRules:
    def test_basic_spec(self):
        r = ShardingRules(_mesh())
        assert r.spec((256, 4096), Axes(("act_batch", "act_embed"))) == \
            P("data", None)
        assert r.spec((4096, 12288), Axes(("embed", "mlp"))) == \
            P("data", "model")

    def test_divisibility_guard(self):
        r = ShardingRules(_mesh())
        # 40 heads % 16 != 0 -> unsharded; flattened 40*128 divides fine
        assert r.spec((40,), Axes(("kv_heads_n",))) == P(None)
        assert r.spec((5120,), Axes(("heads",))) == P("model")
        # odd vocab (minicpm) falls back to replicated
        assert r.spec((122753, 2304), Axes(("vocab", "embed"))) == \
            P(None, "data")

    def test_axis_reuse_guard(self):
        r = ShardingRules(_mesh())
        # (lru, lru) both preferring model: only the first gets it
        spec = r.spec((2560, 2560), Axes(("lru", "lru")))
        assert spec == P("model", None)

    def test_multipod_combined_axis(self):
        r = ShardingRules(_mesh((2, 16, 16), ("pod", "data", "model")))
        assert r.spec((256, 4096), Axes(("act_batch", "act_seq"))) == \
            P(("pod", "data"), "model")
        # batch=1 (long_500k): everything falls back
        assert r.spec((1, 4096), Axes(("act_batch", "act_seq"))) == \
            P(None, "model")

    def test_missing_mesh_axis_skipped(self):
        r = ShardingRules(_mesh())  # no 'pod' axis
        assert r.spec((256,), Axes(("act_batch",))) == P("data")

    def test_override(self):
        r = ShardingRules(_mesh()).override(act_seq=())
        assert r.spec((64, 4096), Axes(("act_batch", "act_seq"))) == \
            P("data", None)

    def test_param_tree_shardings_cover_every_leaf(self):
        from repro.configs.registry import ARCHS, get_config
        from repro.models import lm
        r = ShardingRules(_mesh())
        for arch in ARCHS:
            cfg = get_config(arch)
            abs_p = lm.abstract_params(cfg)
            axes = lm.param_axes(cfg)
            specs = r.tree_specs(abs_p, axes)
            n_leaves = len(jax.tree.leaves(abs_p))
            n_specs = len(jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P)))
            assert n_leaves == n_specs, arch


class TestHloAnalysis:
    def test_type_bytes(self):
        assert type_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
        assert type_bytes("(f32[4,4]{1,0}, s32[7]{0})") == 64 + 28
        assert type_bytes("f32[]") == 4

    def test_collective_parsing_synthetic(self):
        hlo = """
HloModule m
ENTRY %main {
  %p0 = bf16[64,512]{1,0} parameter(0)
  %dot = f32[64,256]{1,0} dot(%p0, %p0)
  %all-reduce.1 = f32[64,256]{1,0} all-reduce(%dot), replica_groups=[8,8]<=[64]
  %ag = bf16[64,512]{1,0} all-gather(%p0), replica_groups=[4,16]<=[64], dimensions={0}
  ROOT %t = (f32[64,256]{1,0}) tuple(%all-reduce.1)
}
"""
        stats = analyze_collectives(hlo)
        ar_bytes = 64 * 256 * 4
        ag_bytes = 64 * 512 * 2
        assert stats.operand_bytes["all-reduce"] == ar_bytes
        assert stats.operand_bytes["all-gather"] == ag_bytes
        assert stats.wire_bytes["all-reduce"] == pytest.approx(
            ar_bytes * 2 * 7 / 8)
        assert stats.wire_bytes["all-gather"] == pytest.approx(ag_bytes * 15)
        assert stats.counts == {"all-reduce": 1, "all-gather": 1}

    def test_async_start_done_pairs_count_once(self):
        """-start results are (operand, result) tuples; the pair must
        count one collective with the sync convention's result bytes."""
        hlo = """
ENTRY %main {
  %p0 = bf16[16,512]{1,0} parameter(0)
  %ags = (bf16[4,512]{1,0}, bf16[16,512]{1,0}) all-gather-start(%p0), replica_groups=[2,4]<=[8], dimensions={0}
  %agd = bf16[16,512]{1,0} all-gather-done(%ags)
}
"""
        stats = analyze_collectives(hlo)
        result_bytes = 16 * 512 * 2
        assert stats.counts == {"all-gather": 1}
        assert stats.operand_bytes["all-gather"] == result_bytes
        assert stats.wire_bytes["all-gather"] == pytest.approx(
            result_bytes * 3)

    def test_real_compiled_module(self):
        """Single-device module: parser must find zero collectives and not
        crash on real XLA output."""
        fn = jax.jit(lambda x: jnp.sum(x * 2.0))
        txt = fn.lower(jnp.ones((8, 8))).compile().as_text()
        stats = analyze_collectives(txt)
        assert stats.total_wire_bytes == 0


class TestMesh:
    def test_make_production_mesh_is_a_function_not_constant(self):
        import repro.launch.mesh as m
        import inspect
        assert callable(m.make_production_mesh)
        src = inspect.getsource(m)
        # no module-level jax mesh/device calls (device state stays clean)
        for line in src.splitlines():
            stripped = line.split("#")[0].rstrip()
            if stripped.startswith((" ", "\t")) or not stripped:
                continue
            assert "make_mesh(" not in stripped, "module-level mesh!"

    def test_dryrun_sets_flags_before_imports(self):
        import pathlib
        src = pathlib.Path("src/repro/launch/dryrun.py").read_text()
        lines = [l for l in src.splitlines() if l.strip()]
        assert lines[0] == "import os"
        assert "xla_force_host_platform_device_count=512" in lines[1]

"""Core GNNerator system tests: sharding, dataflow, engines, models."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.dataflow import (Dataflow, best_order, blocked_vs_conventional,
                                 simulate_traffic, table1_costs)
from repro.core.models import (build_graph_tensors, init_gnn, make_forward,
                               paper_spec)
from repro.core.sharding import max_shard_nodes_for_budget, shard_graph
from repro.graphs.datasets import DATASETS, make_dataset


def _toy_graph(n_nodes=50, n_edges=200, seed=0):
    r = np.random.default_rng(seed)
    e = r.integers(0, n_nodes, (n_edges, 2))
    return e[e[:, 0] != e[:, 1]]


class TestSharding:
    def test_shard_counts_and_blocks(self):
        edges = _toy_graph()
        sg = shard_graph(edges, 50, n=16, normalize="sum")
        assert sg.S == 4 and sg.n_padded == 64
        # every edge (plus self loops) lands in exactly one shard cell
        assert int(sg.occupancy.sum()) == sg.num_edges
        # dense blocks contain the same edge mass
        assert np.isclose(sg.blocks.sum(), sg.num_edges)

    def test_gcn_normalization_row_mass(self):
        edges = _toy_graph()
        sg = shard_graph(edges, 50, n=16, normalize="mean")
        # mean aggregation: each destination row sums to ~1
        a_flat = sg.blocks.transpose(0, 2, 1, 3).reshape(64, 64)
        row = a_flat.sum(axis=1)
        active = row > 0
        np.testing.assert_allclose(row[active], 1.0, atol=1e-5)

    def test_edge_lists_match_blocks(self):
        edges = _toy_graph(seed=3)
        sg = shard_graph(edges, 50, n=16, normalize="sum")
        # rebuild blocks from the COO lists
        rebuilt = np.zeros_like(sg.blocks)
        S, _, E = sg.edge_src.shape
        for i in range(S):
            for j in range(S):
                for e in range(E):
                    if sg.edge_valid[i, j, e]:
                        rebuilt[i, j, sg.edge_dst[i, j, e], sg.edge_src[i, j, e]] += 1
        np.testing.assert_allclose(rebuilt, sg.blocks)

    @settings(max_examples=25, deadline=None)
    @given(n=st.sampled_from([8, 16, 32]), seed=st.integers(0, 999))
    def test_property_no_edges_lost(self, n, seed):
        edges = _toy_graph(60, 150, seed)
        sg = shard_graph(edges, 60, n=n, normalize="sum")
        assert int(sg.edge_valid.sum()) == sg.num_edges

    def test_budget_monotonic_in_block(self):
        # smaller feature block -> more nodes fit (the paper's core lever)
        budget = 24 * 2 ** 20
        ns = [max_shard_nodes_for_budget(budget, b) for b in (512, 128, 64, 16)]
        assert ns == sorted(ns)


class TestDataflow:
    def test_schedule_covers_grid(self):
        df = Dataflow(S=3, D=64, B=16)
        steps = list(df.steps())
        assert len(steps) == 4 * 9
        seen = {(b, i, j) for b, i, j in steps}
        assert len(seen) == 36

    def test_table1_shapes(self):
        c = table1_costs(S=5, I=2.0)
        assert c["dst_stationary"]["write"] == 5
        assert c["src_stationary"]["write"] == 21
        assert c["dst_stationary"]["read"] == 42.0

    def test_best_order_prefers_dst_for_small_I(self):
        assert best_order(S=8, I=1.0) == "dst_stationary"

    def test_traffic_blocked_beats_conventional(self):
        # fixed budget: blocking reduces off-chip traffic (paper §IV-B)
        out = blocked_vs_conventional(num_nodes=20000, D=512, B=64,
                                      onchip_bytes=24 * 2 ** 20)
        assert out["S_blocked"] <= out["S_conventional"]
        assert out["traffic_ratio"] > 1.0

    def test_blocked_traffic_uses_ceil_block_count(self):
        """Regression: with B ∤ D the last partial block still sweeps the
        grid, so blocked traffic must count ceil(D/B)=4 blocks for D=100,
        B=32 — flooring to 3 undercounted traffic by 25%."""
        kw = dict(num_nodes=20000, onchip_bytes=24 * 2 ** 20)
        out = blocked_vs_conventional(D=100, B=32, **kw)
        # same budget/B -> same shard grid; an exactly-divisible D=128 run
        # has 4 blocks too, so the per-block byte rate must match
        out128 = blocked_vs_conventional(D=128, B=32, **kw)
        assert out["S_blocked"] == out128["S_blocked"]
        assert out["offchip_bytes_blocked"] == out128["offchip_bytes_blocked"]
        # and 4 blocks is one-third more traffic than a floor-counted 3
        out96 = blocked_vs_conventional(D=96, B=32, **kw)
        assert out["offchip_bytes_blocked"] == pytest.approx(
            out96["offchip_bytes_blocked"] * 4 / 3)

    def test_simulated_traffic_scales_with_blocks(self):
        # edge list is re-walked D/B times (the paper's stated overhead)
        t1 = simulate_traffic(Dataflow(S=4, D=256, B=256),
                              nodes_per_shard=64, edges_per_shard=100.0)
        t4 = simulate_traffic(Dataflow(S=4, D=256, B=64),
                              nodes_per_shard=64, edges_per_shard=100.0)
        assert t4.onchip_edge_reads == 4 * t1.onchip_edge_reads


class TestModels:
    @pytest.mark.parametrize("kind", ["gcn", "graphsage", "graphsage_pool"])
    def test_forward_shapes_and_finite(self, kind):
        edges = _toy_graph(80, 300, seed=1)
        feats = np.random.default_rng(0).standard_normal((80, 24)).astype(np.float32)
        gt = build_graph_tensors(edges, 80, n=32, kind=kind)
        spec = paper_spec(kind, 24, 5)
        params = init_gnn(jax.random.key(0), spec)
        fwd = make_forward(spec)
        out = fwd(params, gt, gt.group(jnp.asarray(feats)))
        assert out.shape == (80, 5)
        assert bool(jnp.isfinite(out).all())

    def test_gcn_matches_dense_reference(self):
        """System-level oracle: the whole sharded/blocked GNNerator pipeline
        must equal the textbook dense GCN on the same graph."""
        edges = _toy_graph(40, 160, seed=7)
        n_nodes, f_in, f_out = 40, 16, 4
        feats = np.random.default_rng(1).standard_normal((n_nodes, f_in)).astype(np.float32)
        gt = build_graph_tensors(edges, n_nodes, n=16, kind="gcn")
        spec = paper_spec("gcn", f_in, f_out)
        params = init_gnn(jax.random.key(1), spec)
        out = make_forward(spec)(params, gt, gt.group(jnp.asarray(feats)))

        # dense reference: Â = D^-1/2 (A+I) D^-1/2 (per-direction degrees)
        a = np.zeros((n_nodes, n_nodes), np.float32)
        for s, d in edges:
            a[d, s] += 1.0
        a += np.eye(n_nodes, dtype=np.float32)
        din = a.sum(1)
        dout = a.sum(0)
        ahat = a / np.sqrt(np.maximum(np.outer(din, dout), 1.0))
        h = feats
        ws = [np.asarray(l["w"]) for l in params["layers"]]
        for i, w in enumerate(ws):
            h = ahat @ h @ w
            if i < len(ws) - 1:
                h = np.maximum(h, 0)
        np.testing.assert_allclose(np.asarray(out), h, atol=2e-3, rtol=2e-3)

    def test_shard_size_invariance(self):
        """Changing the shard size n (hence S) must not change results."""
        edges = _toy_graph(60, 240, seed=9)
        feats = np.random.default_rng(2).standard_normal((60, 12)).astype(np.float32)
        spec = paper_spec("gcn", 12, 3)
        params = init_gnn(jax.random.key(2), spec)
        fwd = make_forward(spec)
        outs = []
        for n in (16, 32, 64):
            gt = build_graph_tensors(edges, 60, n=n, kind="gcn")
            outs.append(np.asarray(fwd(params, gt, gt.group(jnp.asarray(feats)))))
        np.testing.assert_allclose(outs[0], outs[1], atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(outs[0], outs[2], atol=1e-3, rtol=1e-3)


class TestDatasets:
    @pytest.mark.parametrize("name", list(DATASETS))
    def test_profiles_match_table2(self, name):
        p = DATASETS[name]
        # generate large-regime profiles (reddit: ~115M edges) scaled down
        scale = 1.0 if p.num_edges <= 1_000_000 else 0.05
        ds = make_dataset(name, scale=scale)
        assert ds.features.shape == (ds.profile.num_nodes, p.feature_dim)
        # edge count within 2% of the (scaled) Table II target
        assert (abs(ds.edges.shape[0] - ds.profile.num_edges)
                / ds.profile.num_edges < 0.02)

"""repro.tune: autotuner determinism + memoization, winner-store key
scoping (backend/platform/version), corruption/staleness fallbacks, and
analytic-vs-autotuned output parity through runtime.compile."""
import json

import numpy as np
import pytest

from repro import runtime, tune
from repro.gnn import executor
from repro.gnn.models import ZooSpec
from repro.graphs.datasets import TABLE2_DATASETS, make_dataset
from repro.kernels.registry import OP_NAMES, resolve
from repro.tune.measure import Measurement
from repro.tune.store import TUNER_VERSION, TuneRecord

# scaled so every Table-II profile still yields a multi-shard grid but
# each tuning rep stays milliseconds on the reference backend
SCALES = {"cora": 0.02, "citeseer": 0.015, "pubmed": 0.003}


def _setup(dataset="cora", arch="gcn", scale=0.05, hidden=8):
    ds = make_dataset(dataset, seed=0, scale=scale)
    spec = ZooSpec(arch, ds.profile.feature_dim, hidden,
                   ds.profile.num_classes, num_layers=2)
    return ds, spec


@pytest.fixture(autouse=True)
def _fresh_tune_cache():
    tune.clear_tune_cache()
    yield
    tune.clear_tune_cache()


class _BrokenBackend:
    """Every op raises: models a backend that OOMs/faults on any config."""
    name = "broken"


def _boom(*_a, **_kw):
    raise RuntimeError("kernel exploded")


for _op in OP_NAMES:
    setattr(_BrokenBackend, _op, staticmethod(_boom))


class TestSearch:
    def test_analytic_is_candidate_zero(self):
        ds, spec = _setup()
        analytic = executor.plan_model(spec, ds.profile.num_nodes,
                                       ds.edges.shape[0], max_n=64)
        cands = tune.candidate_plans(spec, ds.profile.num_nodes,
                                     ds.edges.shape[0], analytic=analytic,
                                     max_n=64, budget=8)
        assert cands, "search produced no candidates"
        assert tune.plan_digest(cands[0]) == tune.plan_digest(analytic)
        digests = [tune.plan_digest(c) for c in cands]
        assert len(set(digests)) == len(digests)   # deduped
        assert len(cands) <= 8

    def test_enumeration_is_deterministic(self):
        ds, spec = _setup("citeseer", scale=0.02)
        analytic = executor.plan_model(spec, ds.profile.num_nodes,
                                       ds.edges.shape[0], max_n=64)
        kw = dict(analytic=analytic, max_n=64, top_k=3, budget=12)
        a = tune.candidate_plans(spec, ds.profile.num_nodes,
                                 ds.edges.shape[0], **kw)
        b = tune.candidate_plans(spec, ds.profile.num_nodes,
                                 ds.edges.shape[0], **kw)
        assert [tune.plan_digest(p) for p in a] == \
               [tune.plan_digest(p) for p in b]

    def test_budget_truncates(self):
        ds, spec = _setup()
        analytic = executor.plan_model(spec, ds.profile.num_nodes,
                                       ds.edges.shape[0], max_n=64)
        cands = tune.candidate_plans(spec, ds.profile.num_nodes,
                                     ds.edges.shape[0], analytic=analytic,
                                     max_n=64, budget=2)
        assert len(cands) <= 2
        assert tune.candidate_plans(
            spec, ds.profile.num_nodes, ds.edges.shape[0],
            analytic=analytic, max_n=64, budget=0) == []


class TestStaticPruning:
    """Plan-lint pruning runs inside candidate_plans: doomed or
    execution-identical configs are rejected statically — before any
    measurement — and the tune report says so."""

    def test_pruning_never_removes_analytic_candidate(self):
        ds, spec = _setup()
        analytic = executor.plan_model(spec, ds.profile.num_nodes,
                                       ds.edges.shape[0], max_n=64)
        pruned = []
        cands = tune.candidate_plans(spec, ds.profile.num_nodes,
                                     ds.edges.shape[0], analytic=analytic,
                                     max_n=64, budget=8,
                                     backend_name="reference",
                                     pruned_out=pruned)
        assert tune.plan_digest(cands[0]) == tune.plan_digest(analytic)
        # both traversal orders are enumerated but the runtime executes
        # them identically, so the cora space always holds duplicates
        assert pruned, "expected >= 1 statically-pruned candidate"
        for rec in pruned:
            assert rec["index"] > 0          # analytic #0 is untouchable
            assert rec["reason"] in ("illegal", "duplicate-execution")
            assert rec["detail"]

        from repro.analyze import plan_lint
        for c in cands:                      # kept => legal
            assert [f for f in plan_lint.check_model_plan(
                c, backend_name="reference")
                if f.severity == "error"] == []
        digests = [plan_lint.executed_digest(c) for c in cands]
        assert len(set(digests)) == len(digests)   # kept => distinct program

    def test_tune_report_records_pruned(self, tmp_path):
        ds, spec = _setup()
        be = resolve(None, "reference")
        rec = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes,
                                 backend=be, features=ds.features, max_n=64,
                                 budget=6, reps=1, cache_dir=tmp_path)
        rep = rec.report()
        assert rep["candidates_pruned"] == len(rec.pruned)
        assert rep["candidates_pruned"] >= 1
        assert sum(rep["pruned_reasons"].values()) == rep["candidates_pruned"]
        # pruned classes never reach measurement, so nothing fails there
        assert rep["candidates_failed"] == 0
        back = TuneRecord.from_json(json.loads(json.dumps(rec.to_json())))
        assert back.pruned == rec.pruned


class TestAutotuneMemoization:
    """Same (arch, graph signature, budget, seed) -> identical winner with
    zero re-measurement on the second call."""

    def test_deterministic_and_memoized_in_process(self):
        ds, spec = _setup()
        be = resolve(None, "reference")
        kw = dict(backend=be, features=ds.features, max_n=64,
                  budget=4, seed=0, reps=2, warmup=1)
        rec1 = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes, **kw)
        stats = tune.tune_cache_stats()
        assert rec1.plan_source == "autotune"
        assert stats["measurements"] == rec1.n_measured > 0
        rec2 = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes, **kw)
        stats = tune.tune_cache_stats()
        assert rec2 is rec1                       # in-process memo
        assert stats["hits"] == 1
        assert stats["measurements"] == rec1.n_measured   # nothing re-run
        assert tune.plan_digest(rec2.plan) == tune.plan_digest(rec1.plan)

    def test_disk_memo_survives_restart(self, tmp_path):
        ds, spec = _setup()
        be = resolve(None, "reference")
        kw = dict(backend=be, features=ds.features, max_n=64,
                  budget=3, seed=1, reps=2, cache_dir=tmp_path)
        rec1 = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes, **kw)
        assert list(tmp_path.glob("tune-*.json"))
        tune.clear_tune_cache()                   # "new process"
        rec2 = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes, **kw)
        stats = tune.tune_cache_stats()
        assert stats["disk_hits"] == 1 and stats["measurements"] == 0
        assert tune.plan_digest(rec2.plan) == tune.plan_digest(rec1.plan)
        assert rec2.winner_ms == rec1.winner_ms

    def test_corrupt_disk_entry_falls_back_to_retuning(self, tmp_path):
        ds, spec = _setup()
        be = resolve(None, "reference")
        key = tune.tune_key(spec, ds.profile.num_nodes, ds.edges.shape[0],
                            platform=executor.GNNERATOR, max_n=64,
                            block_candidates=executor._BLOCK_CANDIDATES,
                            backend_name=be.name, budget=3, seed=0,
                            reps=2, warmup=1)
        (tmp_path / f"tune-{key}.json").write_text("{not json!!")
        rec = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes,
                                 backend=be, features=ds.features, max_n=64,
                                 budget=3, seed=0, reps=2, warmup=1,
                                 cache_dir=tmp_path)
        stats = tune.tune_cache_stats()
        assert stats["corrupt"] == 1              # degraded, not raised
        assert rec.plan_source == "autotune"
        assert stats["measurements"] == rec.n_measured > 0

    def test_stale_tuner_version_invalidates(self, tmp_path):
        ds, spec = _setup()
        be = resolve(None, "reference")
        kw = dict(backend=be, features=ds.features, max_n=64,
                  budget=3, seed=2, reps=2, cache_dir=tmp_path)
        tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes, **kw)
        (path,) = tmp_path.glob("tune-*.json")
        blob = json.loads(path.read_text())
        blob["tuner_version"] = TUNER_VERSION + 1
        path.write_text(json.dumps(blob))
        tune.clear_tune_cache()
        rec = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes, **kw)
        stats = tune.tune_cache_stats()
        assert stats["corrupt"] == 1 and stats["disk_hits"] == 0
        assert rec.plan_source == "autotune"      # re-tuned from scratch
        assert stats["measurements"] > 0

    def test_budget_zero_is_analytic_fallback(self):
        ds, spec = _setup()
        rec = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes,
                                 backend=resolve(None, "reference"),
                                 max_n=64, budget=0)
        assert rec.plan_source == "analytic_fallback"
        assert rec.n_measured == 0 and rec.winner_ms is None
        assert tune.tune_cache_stats()["measurements"] == 0
        analytic = executor.plan_model(spec, ds.profile.num_nodes,
                                       ds.edges.shape[0], max_n=64)
        assert tune.plan_digest(rec.plan) == tune.plan_digest(analytic)

    def test_all_candidates_failing_never_raises(self):
        ds, spec = _setup()
        rec = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes,
                                 backend=_BrokenBackend(),
                                 features=ds.features, max_n=64,
                                 budget=3, reps=1)
        assert rec.plan_source == "analytic_fallback"
        assert rec.n_measured > 0
        assert all(m.status == "error" for m in rec.candidates)
        assert all("RuntimeError" in m.error for m in rec.candidates)
        rep = rec.report()
        assert rep["candidates_failed"] == rec.n_measured


class TestKeyScoping:
    """Satellite regression: a winner measured on one backend/platform/
    version must never be served to another, while the *analytic* plan
    memo stays environment-independent (backends share plan objects)."""

    def _key(self, spec, ds, **over):
        kw = dict(platform=executor.GNNERATOR, max_n=64,
                  block_candidates=executor._BLOCK_CANDIDATES,
                  backend_name="reference", budget=4, seed=0, reps=3,
                  warmup=1)
        kw.update(over)
        return tune.tune_key(spec, ds.profile.num_nodes,
                             ds.edges.shape[0], **kw)

    def test_every_scope_axis_is_in_the_key(self):
        ds, spec = _setup()
        base = self._key(spec, ds)
        assert base == self._key(spec, ds)                    # stable
        assert base != self._key(spec, ds, backend_name="pallas")
        assert base != self._key(spec, ds, budget=5)
        assert base != self._key(spec, ds, seed=1)
        assert base != self._key(spec, ds, reps=2)
        assert base != self._key(spec, ds, warmup=2)

    def test_scope_includes_environment(self):
        import jax
        scope = tune.tune_scope("pallas")
        assert scope["backend"] == "pallas"
        assert scope["jax_platform"] == jax.default_backend()
        assert scope["jax_version"] == jax.__version__
        assert scope["tuner_version"] == TUNER_VERSION

    def test_analytic_plan_key_ignores_scope_only_when_absent(self):
        ds, spec = _setup()
        bare = executor.plan_key(spec, ds.profile.num_nodes,
                                 ds.edges.shape[0],
                                 platform=executor.GNNERATOR, max_n=64,
                                 block_candidates=executor._BLOCK_CANDIDATES)
        scoped = executor.plan_key(spec, ds.profile.num_nodes,
                                   ds.edges.shape[0],
                                   platform=executor.GNNERATOR, max_n=64,
                                   block_candidates=executor._BLOCK_CANDIDATES,
                                   scope={"backend": "pallas"})
        assert bare != scoped
        assert bare == executor.plan_key(
            spec, ds.profile.num_nodes, ds.edges.shape[0],
            platform=executor.GNNERATOR, max_n=64,
            block_candidates=executor._BLOCK_CANDIDATES, scope=None)

    def test_winners_not_shared_across_backends(self):
        ds, spec = _setup(scale=0.02)
        kw = dict(features=ds.features, max_n=16, budget=2, reps=1)
        tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes,
                           backend=resolve(None, "reference"), **kw)
        n_ref = tune.tune_cache_stats()["measurements"]
        assert n_ref > 0
        tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes,
                           backend=resolve(None, "jax"), **kw)
        stats = tune.tune_cache_stats()
        assert stats["misses"] == 2               # distinct keys: re-tuned
        assert stats["measurements"] > n_ref

    def test_analytic_plans_still_shared_across_backends(self):
        ds, spec = _setup(scale=0.02)
        store = runtime.GraphStore()
        kw = dict(max_shard_n=16, store=store, graph_key="cora-tiny", seed=0)
        ref = runtime.compile(spec, ds, backend="reference", **kw)
        jx = runtime.compile(spec, ds, backend="jax", **kw)
        assert ref.plan is jx.plan                # content-hash memo shares


class TestRuntimeIntegration:
    def test_compile_autotune_memoizes_and_reports(self):
        ds, spec = _setup()
        store = runtime.GraphStore()
        kw = dict(backend="reference", plan="autotune", tune_budget=3,
                  tune_reps=2, max_shard_n=64, store=store,
                  graph_key="cora-s05")
        exe = runtime.compile(spec, ds, **kw)
        assert exe.plan_source == "autotune"
        assert exe.tune_report["candidates_measured"] > 0
        head, *rest = exe.summary().splitlines()
        assert "plan=autotune" in head
        assert any("autotune: winner" in ln for ln in rest)
        n = runtime.tune_cache_stats()["measurements"]
        exe2 = runtime.compile(spec, ds, **kw)
        assert runtime.tune_cache_stats()["measurements"] == n   # cache hit
        assert exe2.plan == exe.plan

    def test_compile_budget_zero_reports_fallback(self):
        ds, spec = _setup()
        exe = runtime.compile(spec, ds, backend="reference", plan="autotune",
                              tune_budget=0, max_shard_n=64)
        assert exe.plan_source == "analytic_fallback"
        assert "autotune: analytic fallback" in exe.summary()
        logits = exe.forward()
        assert logits.shape == (ds.profile.num_nodes,
                                ds.profile.num_classes)

    def test_compile_rejects_unknown_plan_source(self):
        ds, spec = _setup()
        with pytest.raises(ValueError, match="plan must be"):
            runtime.compile(spec, ds, backend="reference", plan="magic")

    def test_compile_rejects_autotune_on_mesh(self):
        ds, spec = _setup()
        with pytest.raises(ValueError, match="mesh"):
            runtime.compile(spec, ds, backend="reference", plan="autotune",
                            mesh=object())

    def test_measurement_json_roundtrip(self):
        m = Measurement(digest="abc", config=[{"layer": 0, "B": 8}],
                        status="ok", median_ms=1.25, reps_ms=(1.3, 1.25, 1.2),
                        warmup_ms=5.0)
        back = Measurement.from_json(json.loads(json.dumps(m.to_json())))
        assert back == m

    def test_tune_record_json_roundtrip(self, tmp_path):
        ds, spec = _setup()
        be = resolve(None, "reference")
        rec = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes,
                                 backend=be, features=ds.features, max_n=64,
                                 budget=2, reps=1, cache_dir=tmp_path)
        back = TuneRecord.from_json(json.loads(json.dumps(rec.to_json())))
        assert back.plan == rec.plan
        assert back.plan_source == rec.plan_source
        assert back.candidates == rec.candidates


class TestParity:
    """CI acceptance: autotuned-plan outputs match analytic-plan outputs
    (the tuner may only change *how* a layer runs, never its math)."""

    @pytest.mark.parametrize("arch", ("gcn", "sage_mean", "gin"))
    @pytest.mark.parametrize("dataset", sorted(TABLE2_DATASETS))
    def test_autotuned_matches_analytic_parity(self, arch, dataset):
        ds = make_dataset(dataset, seed=1, scale=SCALES[dataset])
        spec = ZooSpec(arch, ds.profile.feature_dim, 8,
                       ds.profile.num_classes, num_layers=2)
        store = runtime.GraphStore()
        kw = dict(backend="reference", max_shard_n=16, store=store,
                  graph_key=dataset, seed=0)
        ana = runtime.compile(spec, ds, **kw)
        tuned = runtime.compile(spec, ds, plan="autotune", tune_budget=3,
                                tune_reps=1, **kw)
        assert tuned.plan_source in ("autotune", "analytic_fallback")
        np.testing.assert_allclose(
            np.asarray(tuned.forward()), np.asarray(ana.forward()),
            atol=1e-4, rtol=1e-4)

"""Graph partitioning + MoE routing property tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.sharding import shard_graph
from repro.graphs.datasets import make_dataset
from repro.graphs.partition import balance_report, partition_graph


class TestPartition:
    def test_comm_matrix_conserves_edges(self):
        ds = make_dataset("cora")
        sg = shard_graph(ds.edges, ds.profile.num_nodes, n=256)
        plan = partition_graph(sg, n_data=4)
        assert plan.comm_matrix.sum() == sg.num_edges

    @settings(max_examples=10, deadline=None)
    @given(n_data=st.sampled_from([2, 4, 8]), n=st.sampled_from([64, 128]))
    def test_property_partition_conserves(self, n_data, n):
        r = np.random.default_rng(n_data * 100 + n)
        edges = r.integers(0, 500, (2000, 2))
        sg = shard_graph(edges, 500, n=n)
        plan = partition_graph(sg, n_data)
        assert plan.comm_matrix.sum() == sg.num_edges
        rep = balance_report(sg, n_data)
        assert rep["imbalance"] >= 1.0
        assert 0.0 <= rep["cross_group_edge_frac"] <= 1.0

    def test_transfer_bytes_scale_with_features(self):
        ds = make_dataset("citeseer")
        sg = shard_graph(ds.edges, ds.profile.num_nodes, n=256)
        plan = partition_graph(sg, 4)
        assert plan.transfer_bytes_per_layer(64) * 2 == pytest.approx(
            plan.transfer_bytes_per_layer(128))


class TestMoEProperties:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 99), top_k=st.sampled_from([1, 2, 4]))
    def test_router_weight_conservation(self, seed, top_k):
        """Sum of combine weights per token == 1 with softmax routing
        (when nothing is dropped)."""
        import dataclasses
        from repro.configs.registry import get_smoke
        from repro.nn.layers import init_leaf
        from repro.nn.moe import moe_apply, moe_struct
        cfg = get_smoke("qwen2-moe-a2.7b")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, top_k=top_k, capacity_factor=float(cfg.moe.num_experts),
            n_shared_experts=0))
        leaf = init_leaf(jax.random.key(seed), jnp.float32)
        p = moe_struct(leaf, "m", cfg)
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.standard_normal((2, 8, cfg.d_model)), jnp.float32)
        # identity experts: w_gate=w_up such that silu(g)*u ≈ passthrough is
        # hard; instead check LINEARITY in the combine weights: scaling all
        # expert outputs by c scales y by c
        y1 = moe_apply(p, x, cfg)
        p2 = dict(p, w_down=p["w_down"] * 2.0)
        y2 = moe_apply(p2, x, cfg)
        np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1),
                                   atol=1e-4, rtol=1e-4)

    def test_no_token_crosses_rows(self):
        """Batched dispatch is row-local: changing row 1's tokens must not
        change row 0's output (the GSPMD-locality invariant)."""
        from repro.configs.registry import get_smoke
        from repro.nn.layers import init_leaf
        from repro.nn.moe import moe_apply, moe_struct
        cfg = get_smoke("llama4-scout-17b-a16e")
        leaf = init_leaf(jax.random.key(0), jnp.float32)
        p = moe_struct(leaf, "m", cfg)
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((2, 16, cfg.d_model)), jnp.float32)
        y = moe_apply(p, x, cfg)
        x2 = x.at[1].set(jnp.asarray(
            r.standard_normal((16, cfg.d_model)), jnp.float32))
        y2 = moe_apply(p, x2, cfg)
        np.testing.assert_allclose(np.asarray(y2[0]), np.asarray(y[0]),
                                   atol=1e-5)

    def test_decode_capacity_has_no_floor_waste(self):
        """E10: with T=1 per row, capacity must be exactly top_k-bounded."""
        from repro.nn.moe import _capacity
        from repro.configs.registry import get_smoke
        m = get_smoke("llama4-scout-17b-a16e").moe
        assert _capacity(1, m) == 1 * m.top_k
        assert _capacity(4096, m) >= 4096 * m.top_k / m.num_experts

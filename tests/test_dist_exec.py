"""Sharded GNN execution (repro.dist.gnn): multi-device parity with the
single-device Executable, measured-vs-modeled communication volume, and
the partition-plan regressions the dist layer depends on.

The full-mesh parity tests need 8 devices; CI runs this file as a
dedicated step under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(tier-1's single real CPU device skips them but still runs the 1-device
mesh smoke + partition tests).
"""
import numpy as np
import pytest
import jax

from repro import runtime
from repro.core.sharding import shard_graph
from repro.gnn.models import ZooSpec
from repro.graphs.datasets import make_dataset
from repro.graphs.partition import balance_report, partition_graph
from repro.launch.mesh import make_mesh_for

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _spec(arch, prof, hidden=16):
    return ZooSpec(arch, prof.feature_dim, hidden, prof.num_classes)


def _mesh8():
    return make_mesh_for(8, model_parallel=2)


class TestShardedParity:
    @needs8
    @pytest.mark.parametrize("dataset", ["cora", "citeseer"])
    @pytest.mark.parametrize("arch", ["gcn", "sage_mean"])
    def test_matches_single_device(self, arch, dataset):
        ds = make_dataset(dataset, seed=0)
        spec = _spec(arch, ds.profile)
        exe = runtime.compile(spec, ds, backend="reference", max_shard_n=256)
        sexe = runtime.compile(spec, ds, backend="reference",
                               max_shard_n=256, mesh=_mesh8())
        np.testing.assert_allclose(
            np.asarray(exe.forward()), np.asarray(sexe.forward()),
            rtol=5e-4, atol=5e-4)

    @needs8
    def test_gin_and_predict_path(self):
        ds = make_dataset("cora", seed=0, scale=0.5)
        spec = _spec("gin", ds.profile, hidden=8)
        exe = runtime.compile(spec, ds, backend="reference", max_shard_n=128)
        sexe = runtime.compile(spec, ds, backend="reference",
                               max_shard_n=128, mesh=_mesh8())
        np.testing.assert_allclose(
            np.asarray(exe.forward()), np.asarray(sexe.forward()),
            rtol=5e-4, atol=5e-4)
        ids = [0, 7, ds.profile.num_nodes - 1]
        c1, p1 = exe.predict(ids)
        c2, p2 = sexe.predict(ids)
        np.testing.assert_array_equal(c1, c2)
        np.testing.assert_allclose(p1, p2, atol=1e-5)

    @needs8
    def test_pallas_kernels_run_under_shard_map(self):
        ds = make_dataset("cora", seed=0, scale=0.2)
        spec = _spec("gcn", ds.profile, hidden=8)
        exe = runtime.compile(spec, ds, backend="pallas", max_shard_n=128)
        sexe = runtime.compile(spec, ds, backend="pallas",
                               max_shard_n=128, mesh=_mesh8())
        np.testing.assert_allclose(
            np.asarray(exe.forward()), np.asarray(sexe.forward()),
            rtol=5e-4, atol=5e-4)

    def test_single_device_mesh_smoke(self):
        """A (N, 1) mesh over whatever devices exist always works — the
        shard_map path itself is exercised even on 1 device."""
        ds = make_dataset("cora", seed=0, scale=0.2)
        spec = _spec("gcn", ds.profile, hidden=8)
        mesh = make_mesh_for(jax.device_count(), model_parallel=1)
        exe = runtime.compile(spec, ds, backend="reference", max_shard_n=128)
        sexe = runtime.compile(spec, ds, backend="reference",
                               max_shard_n=128, mesh=mesh)
        np.testing.assert_allclose(
            np.asarray(exe.forward()), np.asarray(sexe.forward()),
            rtol=5e-4, atol=5e-4)

    def test_unsupported_archs_raise(self):
        ds = make_dataset("cora", seed=0, scale=0.1)
        mesh = make_mesh_for(jax.device_count(), model_parallel=1)
        for arch in ("gat", "sage_max"):
            with pytest.raises(NotImplementedError):
                runtime.compile(_spec(arch, ds.profile, hidden=8), ds,
                                backend="reference", max_shard_n=128,
                                mesh=mesh)


class TestShardedComm:
    @needs8
    def test_measured_allgather_matches_partition_plan(self):
        """The compiled module's all-gather wire bytes (HLO-parsed) must
        equal the PartitionPlan's broadcast model, and stay within the
        per-edge-pull upper bound for these (dense-enough) graphs."""
        ds = make_dataset("cora", seed=0)
        spec = _spec("gcn", ds.profile)
        sexe = runtime.compile(spec, ds, backend="reference",
                               max_shard_n=256, mesh=_mesh8())
        cs = sexe.verify_comm()   # asserts measured == modeled
        assert cs["measured_counts"]["all-gather"] == len(spec.layer_dims)
        # one psum per gcn layer (row-parallel dense reduction)
        assert cs["measured_counts"]["all-reduce"] == len(spec.layer_dims)
        edge_bound = sum(cs["plan_transfer_bytes_per_layer"].values())
        assert 0 < cs["measured_allgather_wire_bytes"] <= edge_bound

    @needs8
    def test_serving_engine_serves_sharded(self):
        from repro.serving import Completed, SchedulerConfig, Server
        from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

        ds = make_dataset("cora", seed=0, scale=0.5)
        engine = GNNServeEngine(max_shard_n=128, backend="reference",
                                mesh=_mesh8())
        engine.register_graph("cora", ds)
        engine.register_model("gcn", _spec("gcn", ds.profile, hidden=8))
        server = Server(engine, SchedulerConfig(max_batch_size=4))
        rng = np.random.default_rng(0)
        tickets = [server.submit(NodeRequest(
            "cora", rng.integers(0, ds.profile.num_nodes, 4), "gcn"))
            for _ in range(8)]
        server.drain()
        outs = [t.result() for t in tickets]
        assert all(isinstance(o, Completed) for o in outs)
        # parity against a single-device compile of the same model
        exe = runtime.compile(_spec("gcn", ds.profile, hidden=8), ds,
                              backend="reference", max_shard_n=128,
                              params=engine._models["gcn"].params)
        for t, o in zip(tickets, outs):
            c_ref, _ = exe.predict(o.value.node_ids)
            np.testing.assert_array_equal(o.value.classes, c_ref)


class TestShardedTraining:
    """Data-parallel GNN training over the mesh (runtime.fit mesh path):
    the shard_map transpose psums gradients over the data axis, so grads
    and the trained trajectory must match a single-device run."""

    @needs8
    @pytest.mark.parametrize("arch", ["gcn", "sage_mean", "gin"])
    def test_grads_match_single_device(self, arch):
        import jax.numpy as jnp

        from repro.runtime.fit import masked_cross_entropy

        ds = make_dataset("cora", seed=0, scale=0.5)
        spec = _spec(arch, ds.profile, hidden=8)
        exe = runtime.compile(spec, ds, backend="reference", max_shard_n=128)
        sexe = runtime.compile(spec, ds, backend="reference",
                               max_shard_n=128, mesh=_mesh8(),
                               params=exe.params)
        labels = jnp.asarray(ds.labels.astype(np.int32))
        mask = jnp.asarray(ds.train_mask)

        def grads(e):
            fwd = e._forward_fn()
            loss = lambda p: masked_cross_entropy(
                fwd(p, e._h_grouped), labels, mask)
            return jax.grad(loss)(e.params)

        for a, b in zip(jax.tree.leaves(grads(exe)),
                        jax.tree.leaves(grads(sexe))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)

    @needs8
    def test_sharded_fit_matches_single_device_params(self):
        ds = make_dataset("cora", seed=0, scale=0.3)
        spec = _spec("gcn", ds.profile, hidden=8)
        kw = dict(steps=3, lr=1e-2, backend="reference", max_shard_n=128,
                  log=lambda s: None)
        single = runtime.fit(spec, ds, **kw)
        sharded = runtime.fit(spec, ds, mesh=_mesh8(), **kw)
        for a, b in zip(jax.tree.leaves(single.params),
                        jax.tree.leaves(sharded.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-4)

    @needs8
    def test_train_step_collectives_verified(self):
        """The jitted TRAIN step's collective volume: at least the
        forward all-gather model on the wire, plus reduction collectives
        carrying the data-parallel gradient psum."""
        ds = make_dataset("cora", seed=0, scale=0.3)
        spec = _spec("gcn", ds.profile, hidden=8)
        res = runtime.fit(spec, ds, steps=1, backend="reference",
                          max_shard_n=128, mesh=_mesh8(),
                          log=lambda s: None)
        cs = res.trainable.verify_train_comm()   # asserts internally
        assert cs["measured_wire_bytes"]["all-gather"] >= \
            cs["forward_allgather_wire_bytes"] * 0.98
        reduces = sum(cs["measured_counts"].get(k, 0)
                      for k in ("all-reduce", "reduce-scatter"))
        assert reduces > 0


class TestPartitionRegressions:
    def test_no_empty_trailing_groups(self):
        """S=4 rows over n_data=3: the old ceil-division assignment gave
        (2, 2, 0) — an empty group diluting balance_report's mean. The
        balanced split must give (2, 1, 1) with every group owning
        edges."""
        rng = np.random.default_rng(0)
        edges = rng.integers(0, 512, (4000, 2))
        sg = shard_graph(edges, 512, n=128)     # S = 4
        assert sg.S == 4
        plan = partition_graph(sg, 3)
        assert plan.group_sizes == (2, 1, 1)
        per_group = plan.comm_matrix.sum(axis=1)
        assert (per_group > 0).all()
        assert plan.comm_matrix.sum() == sg.num_edges
        rep = balance_report(sg, 3)
        # mean over 3 real groups, not diluted by an empty one
        assert rep["edges_per_group_mean"] == pytest.approx(
            sg.num_edges / 3)
        assert rep["imbalance"] >= 1.0

    def test_padded_split_matches_executable_grouping(self):
        rng = np.random.default_rng(1)
        edges = rng.integers(0, 640, (4000, 2))
        sg = shard_graph(edges, 640, n=128)     # S = 5
        plan = partition_graph(sg, 4, pad=True)
        # ceil(5/4) = 2 rows per group; trailing groups own the remainder
        assert plan.rows_per_group == 2
        assert plan.group_sizes == (2, 2, 1, 0)
        assert plan.comm_matrix.sum() == sg.num_edges

    def test_allgather_model_scales_with_features_and_groups(self):
        rng = np.random.default_rng(2)
        edges = rng.integers(0, 512, (2000, 2))
        sg = shard_graph(edges, 512, n=64)
        plan = partition_graph(sg, 4, pad=True)
        b1 = plan.allgather_bytes_per_layer(32, 64)
        assert b1 == plan.allgather_bytes_per_layer(64, 64) / 2
        assert b1 == (4 - 1) * 4 * plan.rows_per_group * 64 * 32 * 2

"""Shared test configuration.

The property tests use ``hypothesis`` when it is installed (see
requirements-dev.txt). In minimal environments without it, importing the
test modules used to *error* at collection and take the whole tier-1 run
down with them. Instead we install a deterministic mini-fallback into
``sys.modules`` before collection: ``@given`` runs each test over a small,
fixed sample of its strategies (diagonal sampling across the example
lists), and ``@settings`` becomes a no-op. Real hypothesis, when present,
always wins.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import sys
import types

def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end test (subprocess runs)")


try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    _MAX_RUNS = 8

    class _Strategy:
        """A strategy is just a fixed, ordered list of example values."""

        def __init__(self, examples):
            self.examples = list(examples)
            if not self.examples:
                raise ValueError("strategy needs at least one example")

    def _sampled_from(seq):
        return _Strategy(seq)

    def _integers(min_value=0, max_value=0):
        mid = (min_value + max_value) // 2
        return _Strategy(dict.fromkeys([min_value, mid, max_value]))

    def _booleans():
        return _Strategy([False, True])

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        mid = 0.5 * (min_value + max_value)
        return _Strategy(dict.fromkeys([min_value, mid, max_value]))

    def _given(*arg_strategies, **kw_strategies):
        if arg_strategies:
            raise TypeError(
                "fallback @given supports keyword strategies only")

        def deco(fn):
            names = list(kw_strategies)
            exs = [kw_strategies[n].examples for n in names]
            # enumerate the full cartesian product (strategies here carry a
            # handful of examples each) and take evenly spaced combos, so
            # mixed off-diagonal combinations are exercised too
            combos = list(itertools.product(*exs))
            step = max(1, len(combos) // _MAX_RUNS)
            picked = combos[::step][:_MAX_RUNS]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for combo in picked:
                    fn(*args, **dict(zip(names, combo)), **kwargs)

            # pytest resolves fixtures from the *wrapped* signature; strip
            # the strategy-bound parameters so they aren't mistaken for
            # fixtures (and drop __wrapped__, which would leak them back)
            sig = inspect.signature(fn)
            params = [p for p in sig.parameters.values()
                      if p.name not in kw_strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def _settings(*_a, **_kw):
        def deco(fn):
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.sampled_from = _sampled_from
    _st.integers = _integers
    _st.booleans = _booleans
    _st.floats = _floats

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    _hyp.__fallback__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

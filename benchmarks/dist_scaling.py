"""Single- vs multi-device sharded GNN execution, recorded to
BENCH_gnn.json (section ``dist_scaling``).

    PYTHONPATH=src python -m benchmarks.dist_scaling

Forces 8 virtual host devices (so it must run standalone, not from
benchmarks.run — jax pins the device count at first init) and compares,
per (arch, graph):

  * full-graph forward latency of the single-device Executable vs the
    sharded one on a data=4 x model=2 mesh,
  * the sharded module's measured cross-device traffic (HLO-parsed
    all-gather / all-reduce wire bytes) against the PartitionPlan models,
  * the partition balance report (cross-group edge fraction, imbalance).

On this container the 8 "devices" are slices of one CPU, so sharded
wall-clock measures SPMD overhead rather than speedup; the numbers that
transfer to real multi-chip runs are the communication volumes and the
balance profile.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time                   # noqa: E402

import numpy as np            # noqa: E402

from benchmarks.report import merge_bench_json  # noqa: E402

DEVICES = 8
MODEL_PARALLEL = 2
ARCHS = ("gcn", "sage_mean")
GRAPHS = (("cora", 1.0), ("citeseer", 1.0))
ITERS = 5
BACKEND = "reference"


def _time_forward(exe, iters: int = ITERS) -> float:
    import jax
    jax.block_until_ready(exe.forward())        # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(exe.forward())
    return (time.perf_counter() - t0) / iters * 1e3


def bench_dist_scaling():
    import jax

    from repro import runtime
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.launch.mesh import make_mesh_for

    assert jax.device_count() >= DEVICES, (
        f"needs {DEVICES} devices; run standalone so the XLA_FLAGS "
        f"override above takes effect (got {jax.device_count()})")
    mesh = make_mesh_for(DEVICES, model_parallel=MODEL_PARALLEL)
    n_data = DEVICES // MODEL_PARALLEL

    rows = []
    for graph, scale in GRAPHS:
        ds = make_dataset(graph, seed=0, scale=scale)
        for arch in ARCHS:
            spec = ZooSpec(arch, ds.profile.feature_dim, 16,
                           ds.profile.num_classes)
            exe = runtime.compile(spec, ds, backend=BACKEND,
                                  max_shard_n=256)
            sexe = runtime.compile(spec, ds, backend=BACKEND,
                                   max_shard_n=256, mesh=mesh)
            np.testing.assert_allclose(
                np.asarray(exe.forward()), np.asarray(sexe.forward()),
                rtol=5e-4, atol=5e-4)
            single_ms = _time_forward(exe)
            sharded_ms = _time_forward(sexe)
            cs = sexe.verify_comm()
            # balance of the grouping the executable actually ran (the
            # padded equal split over the planner-chosen shard grid)
            per_group = sexe.partition.comm_matrix.sum(axis=1)
            imbalance = float(per_group.max() / max(per_group.mean(), 1.0))
            rows.append({
                "graph": graph, "arch": arch,
                "nodes": ds.profile.num_nodes,
                "edges": int(ds.edges.shape[0]),
                "single_device_ms": round(single_ms, 3),
                "sharded_8dev_ms": round(sharded_ms, 3),
                "nodes_per_s_single": round(
                    ds.profile.num_nodes / (single_ms / 1e3), 1),
                "nodes_per_s_sharded": round(
                    ds.profile.num_nodes / (sharded_ms / 1e3), 1),
                "allgather_wire_bytes":
                    cs["measured_allgather_wire_bytes"],
                "allreduce_wire_bytes":
                    cs["measured_wire_bytes"].get("all-reduce", 0.0),
                "plan_edge_pull_bound_bytes": sum(
                    cs["plan_transfer_bytes_per_layer"].values()),
                "cross_group_edge_frac": round(
                    cs["cross_group_edge_frac"], 4),
                "imbalance": round(imbalance, 3),
            })
            print(f"{graph:10s} {arch:10s} single {single_ms:8.1f} ms | "
                  f"sharded {sharded_ms:8.1f} ms | "
                  f"ag {rows[-1]['allgather_wire_bytes'] / 2**20:7.1f} MiB "
                  f"(edge-pull bound "
                  f"{rows[-1]['plan_edge_pull_bound_bytes'] / 2**20:.1f} "
                  f"MiB)", flush=True)

    payload = {
        "devices": DEVICES,
        "mesh": {"data": n_data, "model": MODEL_PARALLEL},
        "backend": BACKEND,
        "iters": ITERS,
        "note": "8 virtual host devices on one CPU: wall-clock measures "
                "SPMD overhead, not speedup; comm volumes are exact",
        "rows": rows,
    }
    merge_bench_json("dist_scaling", payload)
    derived = (f"{len(rows)} cells, mesh data={n_data} x "
               f"model={MODEL_PARALLEL}")
    return rows, derived


def main() -> None:
    t0 = time.perf_counter()
    rows, derived = bench_dist_scaling()
    us = (time.perf_counter() - t0) * 1e6
    print(f'dist_scaling,{us:.1f},"{derived}"')


if __name__ == "__main__":
    main()

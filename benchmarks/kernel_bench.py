"""Kernel microbenchmarks: wall-time of the Pallas kernels (interpret mode
on this CPU container — TPU timings come from the roofline terms, not from
here) vs the pure-jnp oracles, plus the GNN layer pipeline."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.engines import GNNeratorController, GraphTensors
from repro.core.models import build_graph_tensors, init_gnn, make_forward, paper_spec
from repro.graphs.datasets import make_dataset
from repro.kernels import ops, ref


def _time(fn, *args, reps: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us


def bench_kernels():
    rng = np.random.default_rng(0)
    rows = []
    # dense engine
    x = rng.standard_normal((512, 512)).astype(np.float32)
    w = rng.standard_normal((512, 256)).astype(np.float32)
    rows.append({"kernel": "dense_engine_512x512x256",
                 "pallas_us": round(_time(lambda: ops.dense_matmul(x, w)), 1),
                 "ref_us": round(_time(lambda: ref.dense_engine(x, w)), 1)})
    # shard spmm
    s, n, d = 4, 128, 256
    a = (rng.random((s, s, n, n)) < 0.05).astype(np.float32)
    h = rng.standard_normal((s, n, d)).astype(np.float32)
    rows.append({"kernel": f"shard_spmm_S{s}_n{n}_D{d}",
                 "pallas_us": round(_time(lambda: ops.graph_aggregate(a, h)), 1),
                 "ref_us": round(_time(lambda: ref.shard_spmm(a, h)), 1)})
    # fused layer
    wgt = rng.standard_normal((d, 64)).astype(np.float32)
    rows.append({"kernel": "fused_gnn_layer",
                 "pallas_us": round(_time(
                     lambda: ops.fused_aggregate_extract(a, h, wgt)), 1),
                 "ref_us": round(_time(lambda: ref.fused_gnn(a, h, wgt)), 1)})
    # e2e GCN forward on cora
    ds = make_dataset("cora")
    gt = build_graph_tensors(ds.edges, ds.profile.num_nodes, 512, "gcn")
    spec = paper_spec("gcn", ds.profile.feature_dim, ds.profile.num_classes)
    params = init_gnn(jax.random.key(0), spec)
    fwd = make_forward(spec)
    import jax.numpy as jnp
    hg = gt.group(jnp.asarray(ds.features))
    rows.append({"kernel": "gcn_cora_forward_e2e",
                 "pallas_us": round(_time(lambda: fwd(params, gt, hg), reps=1), 1),
                 "ref_us": float("nan")})
    return rows, {"kernels_benchmarked": len(rows)}

"""Kernel microbenchmarks: wall-time of every registry backend per op
(`pallas` runs in interpret mode on this CPU container — TPU timings come
from the roofline terms, not from here), plus the e2e zoo forward through
``runtime.compile`` on each backend."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.kernels import registry

BACKENDS = ("pallas", "jax", "reference")


def _time(fn, *args, reps: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us


def bench_kernels():
    rng = np.random.default_rng(0)
    rows = []

    # per-op inputs
    x = rng.standard_normal((512, 512)).astype(np.float32)
    w = rng.standard_normal((512, 256)).astype(np.float32)
    s, n, d = 4, 128, 256
    a = (rng.random((s, s, n, n)) < 0.05).astype(np.float32)
    h = rng.standard_normal((s, n, d)).astype(np.float32)
    wgt = rng.standard_normal((d, 64)).astype(np.float32)
    es = rng.integers(0, n, (s, s, 300)).astype(np.int32)
    ed = rng.integers(0, n, (s, s, 300)).astype(np.int32)
    ev = rng.random((s, s, 300)) < 0.8

    cases = {
        "dense_matmul_512x512x256":
            lambda be: be.dense_matmul(x, w),
        f"graph_aggregate_S{s}_n{n}_D{d}":
            lambda be: be.graph_aggregate(a, h),
        "fused_aggregate_extract":
            lambda be: be.fused_aggregate_extract(a, h, wgt),
        "gather_aggregate_max":
            lambda be: be.gather_aggregate(es, ed, ev, h, op="max"),
    }
    for kernel, fn in cases.items():
        row = {"kernel": kernel}
        for name in BACKENDS:
            be = registry.get_backend(name)
            row[f"{name}_us"] = round(_time(fn, be), 1)
        rows.append(row)

    # e2e GCN forward on cora through the runtime, per backend
    from repro import runtime
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset

    ds = make_dataset("cora")
    spec = ZooSpec("gcn", ds.profile.feature_dim, 16,
                   ds.profile.num_classes, num_layers=2)
    row = {"kernel": "gcn_cora_forward_e2e"}
    for name in BACKENDS:
        exe = runtime.compile(spec, ds, backend=name, max_shard_n=512)
        row[f"{name}_us"] = round(_time(lambda: exe.forward(), reps=1), 1)
    rows.append(row)
    return rows, {"kernels_benchmarked": len(rows),
                  "backends": list(BACKENDS)}

"""Autotuner benchmark: analytic vs measured-winner layer plans on the
Pallas backend, recorded to BENCH_gnn.json (`autotune` section).

For each Table-II graph (scaled down — off-TPU the Pallas kernels run in
interpret mode, which pays a large per-element cost), compile the gcn
zoo model twice on the pallas backend:

  * ``plan="autotune"`` — the repro.tune harness measures up to
    ``budget`` candidate plans (the analytic Table-I plan is always
    candidate #0) and picks the fastest median forward.
  * a second ``plan="autotune"`` compile — must hit the persistent
    winner store with **zero** new candidate measurements (the
    acceptance criterion for the tuner's memoization).

Each row records the measured analytic and autotuned medians, the
speedup (>= 1 by construction whenever the analytic candidate measures
ok), the winning per-layer config, and whether the second compile was a
pure cache hit.

    PYTHONPATH=src python -m benchmarks.gnn_autotune --budget 6
"""
from __future__ import annotations

import argparse
import time

from benchmarks.report import merge_bench_json

# (name, scale): calibrated so one interpret-mode forward stays well under
# a second (citeseer smallest: its 3703-dim features dominate the cost)
GRAPHS = (("cora", 0.25), ("citeseer", 0.15), ("pubmed", 0.05))
ARCH = "gcn"
BACKEND = "pallas"
BUDGET = 6
MAX_SHARD_N = 256
TIMEOUT_S = 120.0


def bench_gnn_autotune(budget: int = BUDGET, backend: str = BACKEND):
    from repro import env, runtime
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset

    runtime.clear_tune_cache()
    rows = []
    for name, scale in GRAPHS:
        ds = make_dataset(name, seed=0, scale=scale)
        prof = ds.profile
        spec = ZooSpec(ARCH, prof.feature_dim, 16, prof.num_classes,
                       num_layers=2)
        store = runtime.GraphStore(max_entries=8)
        kw = dict(backend=backend, plan="autotune", tune_budget=budget,
                  tune_timeout_s=TIMEOUT_S, max_shard_n=MAX_SHARD_N,
                  store=store, graph_key=prof.name)

        t0 = time.perf_counter()
        exe = runtime.compile(spec, ds, **kw)
        tune_s = time.perf_counter() - t0
        rep = exe.tune_report

        before = runtime.tune_cache_stats()["measurements"]
        exe2 = runtime.compile(spec, ds, **kw)
        remeasured = runtime.tune_cache_stats()["measurements"] - before

        rows.append({
            "graph": prof.name, "arch": ARCH, "backend": backend,
            "plan_source": exe.plan_source, "scale": scale,
            "nodes": prof.num_nodes, "edges": int(ds.edges.shape[0]),
            "analytic_ms": rep["analytic_ms"],
            "autotuned_ms": rep["winner_ms"],
            "speedup": rep["speedup"],
            "winner_config": rep["winner_config"],
            "candidates_measured": rep["candidates_measured"],
            "candidates_failed": rep["candidates_failed"],
            "tune_wall_s": round(tune_s, 2),
            "winner_cache_hit": bool(remeasured == 0
                                     and exe2.plan == exe.plan),
        })
        print(f"[autotune] {prof.name} ({backend}): analytic "
              f"{rep['analytic_ms']} ms -> winner {rep['winner_ms']} ms "
              f"({rep['speedup']}x, {rep['candidates_measured']} measured, "
              f"{rep['candidates_failed']} failed; cache hit on recompile: "
              f"{rows[-1]['winner_cache_hit']})")

    merge_bench_json("autotune", {
        "backend": backend, "arch": ARCH, "budget": budget,
        "env": env.describe(), "rows": rows})
    derived = {
        "min_speedup": min(r["speedup"] for r in rows),
        "max_speedup": max(r["speedup"] for r in rows),
        "all_cache_hits": all(r["winner_cache_hit"] for r in rows),
        "recorded": "BENCH_gnn.json",
    }
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=BUDGET)
    ap.add_argument("--backend", default=BACKEND,
                    choices=["pallas", "jax", "reference"])
    args = ap.parse_args()

    from repro import env
    env.pin_for_benchmarks()
    rows, derived = bench_gnn_autotune(budget=args.budget,
                                       backend=args.backend)
    print(derived)


if __name__ == "__main__":
    main()

"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure (paper_tables.py), kernel
microbenchmarks (kernel_bench.py), and the roofline analysis over the
dry-run artifacts (roofline.py). Prints ``name,us_per_call,derived`` CSV
rows per the harness contract, with the detailed tables after.
"""
from __future__ import annotations

import sys
import time


def _csv(name: str, us: float, derived) -> None:
    print(f'{name},{us:.1f},"{derived}"')


def _run(name: str, fn, *args):
    t0 = time.perf_counter()
    rows, derived = fn(*args)
    us = (time.perf_counter() - t0) * 1e6
    _csv(name, us, derived)
    return rows, derived


def main() -> None:
    from repro import env
    env.pin_for_benchmarks()

    from benchmarks.gnn_autotune import bench_gnn_autotune
    from benchmarks.gnn_serve import bench_gnn_serve
    from benchmarks.kernel_bench import bench_kernels
    from benchmarks.paper_tables import (bench_fig3, bench_fig4, bench_fig5,
                                         bench_table1, bench_table5)
    from benchmarks.roofline import bench_roofline, markdown_table
    from benchmarks.runtime_compile import bench_runtime_compile

    print("name,us_per_call,derived")
    all_rows = {}
    all_rows["table1_dataflow_costs"] = _run("table1_dataflow_costs", bench_table1)
    all_rows["fig3_gpu_speedup"] = _run("fig3_gpu_speedup", bench_fig3)
    all_rows["table5_vs_hygcn"] = _run("table5_vs_hygcn", bench_table5)
    all_rows["fig4_block_sweep"] = _run("fig4_block_sweep", bench_fig4)
    all_rows["fig5_scaling"] = _run("fig5_scaling", bench_fig5)
    all_rows["kernels"] = _run("kernels_microbench", bench_kernels)
    all_rows["gnn_serve"] = _run("gnn_serve", bench_gnn_serve)
    all_rows["runtime_compile"] = _run("runtime_compile",
                                       bench_runtime_compile)
    all_rows["gnn_autotune"] = _run("gnn_autotune", bench_gnn_autotune)
    all_rows["roofline"] = _run("roofline", bench_roofline)

    print("\n=== detailed tables ===", file=sys.stderr)
    for name, (rows, derived) in all_rows.items():
        print(f"\n--- {name}: {derived}", file=sys.stderr)
        if name != "roofline":
            for r in rows:
                print("   ", r, file=sys.stderr)
    ro_rows, _ = all_rows["roofline"]
    if ro_rows:
        print("\n--- roofline (single-pod) ---", file=sys.stderr)
        print(markdown_table(ro_rows, "single"), file=sys.stderr)


if __name__ == "__main__":
    main()

"""GNN serving benchmark: requests/sec + latency percentiles of the
serving stack across the three Table-II citation graphs, recorded to
BENCH_gnn.json.

Three regimes:
  * cold    — first request per (model, graph): compiles the Executable
              (plan + shard + jit; under ``--plan autotune`` also the
              candidate measurements) and runs full-graph inference (the
              amortized unit of work).
  * warm    — steady-state request stream answered from the Executable's
              cached full-graph softmax (GNNIE's \"accelerator wins become
              end-user wins\" path).
  * poisson — open-loop Poisson arrivals through the continuous-batching
              Server on a simulated arrival clock (engine service time is
              real measured wall time), recording p50/p95/p99 end-to-end
              latency (queue + engine) and the peak queue depth the
              scheduler absorbed. Run on cora at ~80% of the measured warm
              throughput, so queueing is real but stable.

The sweep covers both backends: reference rows (pure jnp, full-scale
graphs) measure the serving stack itself; pallas rows run the same stack
through the Pallas kernels (interpret mode off-TPU, hence the reduced
graph scales). Every row records its backend and plan source.

    PYTHONPATH=src python -m benchmarks.gnn_serve \
        --backends reference,pallas --plan autotune
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.report import merge_bench_json

# (name, scale) per backend: pubmed's densified (S·n)² grid at full scale
# is ~1.5 GiB, too big for a CPU smoke benchmark; the pallas rows shrink
# further because interpret mode pays a large per-element cost (citeseer
# hardest: its 3703-dim features dominate).
GRAPHS = {
    "reference": (("cora", 1.0), ("citeseer", 1.0), ("pubmed", 0.15)),
    "pallas": (("cora", 0.25), ("citeseer", 0.15), ("pubmed", 0.05)),
}
SHARD_N = {"reference": 512, "pallas": 256}
WARM_REQUESTS = 256
POISSON_REQUESTS = 512
POISSON_BATCH = 8
DEFAULT_BACKENDS = ("reference", "pallas")


def _poisson_regime(engine, graph: str, num_nodes: int,
                    rate_rps: float) -> dict:
    """Open-loop arrivals at ``rate_rps`` through the Server.

    The Server runs on a simulated clock: each arrival advances the clock
    to its (virtual) arrival time, each engine step advances it by the
    step's real measured wall time — so queueing delay is what a single
    busy server would actually accumulate at that offered load,
    independent of how fast this harness loops.
    """
    from repro.serving import Completed, SchedulerConfig, Server

    from repro.serving.gnn_engine import NodeRequest

    clk = {"now": 0.0}
    server = Server(engine,
                    SchedulerConfig(max_batch_size=POISSON_BATCH,
                                    max_queue_depth=4096),
                    clock=lambda: clk["now"])
    rng = np.random.default_rng(1)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps,
                                         size=POISSON_REQUESTS))
    tickets = []
    i = 0
    while i < len(arrivals) or server.queue_depth() > 0:
        if server.queue_depth() == 0 and i < len(arrivals):
            clk["now"] = max(clk["now"], arrivals[i])   # idle: jump ahead
        while i < len(arrivals) and arrivals[i] <= clk["now"]:
            ids = rng.integers(0, num_nodes, size=8)
            # stamp the ticket at its virtual arrival, not the post-step
            # clock: wait accrued while the engine was busy must count
            # (submissions are in arrival order, so this is monotone)
            t_now, clk["now"] = clk["now"], arrivals[i]
            tickets.append(server.submit(NodeRequest(graph, ids,
                                                     model="gcn")))
            clk["now"] = t_now
            i += 1
        t0 = time.perf_counter()
        n = server.step(force=True)
        if n:                       # engine busy time passes on the clock
            clk["now"] += time.perf_counter() - t0

    lat = [o.latency_ms for o in (t.result() for t in tickets)
           if isinstance(o, Completed)]
    p50, p95, p99 = np.percentile(lat, [50, 95, 99])
    m = server.metrics()
    return {
        "rate_rps": round(rate_rps, 1), "requests": POISSON_REQUESTS,
        "max_batch_size": POISSON_BATCH,
        "p50_ms": round(float(p50), 3), "p95_ms": round(float(p95), 3),
        "p99_ms": round(float(p99), 3),
        "peak_queue_depth": m["peak_queue_depth"],
        "batches": m["batches"],
        "mean_batch": round(m["dispatched"] / m["batches"], 2),
    }


def bench_gnn_serve(backends=DEFAULT_BACKENDS, plan: str = "analytic",
                    tune_budget: int = 4):
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

    rows = []
    poisson = None
    for backend in backends:
        # reference rows always use the analytic plan (the tuner's winners
        # are environment-scoped per backend; the sweep's `plan` knob
        # targets the backend being tuned)
        be_plan = plan if backend != "reference" else "analytic"
        for name, scale in GRAPHS[backend]:
            ds = make_dataset(name, seed=0, scale=scale)
            prof = ds.profile
            engine = GNNServeEngine(max_shard_n=SHARD_N[backend],
                                    backend=backend, plan=be_plan,
                                    tune_budget=tune_budget)
            engine.register_graph(name, ds)
            engine.register_model("gcn",
                                  ZooSpec("gcn", prof.feature_dim, 16,
                                          prof.num_classes, num_layers=2))

            rng = np.random.default_rng(0)

            def req():
                ids = rng.integers(0, prof.num_nodes, size=8)
                return NodeRequest(name, ids, model="gcn")

            t0 = time.perf_counter()
            engine.serve([req()])
            cold_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            engine.serve([req() for _ in range(WARM_REQUESTS)])
            warm_s = time.perf_counter() - t0
            warm_rps = WARM_REQUESTS / warm_s

            s = engine.stats
            rows.append({
                "graph": prof.name, "backend": backend,
                "plan_source": be_plan, "nodes": prof.num_nodes,
                "edges": int(ds.edges.shape[0]), "scale": scale,
                "cold_ms": round(cold_s * 1e3, 2),
                "warm_req_per_s": round(warm_rps, 1),
                "logits_cache_hits": s["logits_cache_hits"],
                "logits_cache_misses": s["logits_cache_misses"],
            })
            if backend == "reference" and name == "cora":
                poisson = _poisson_regime(engine, name, prof.num_nodes,
                                          rate_rps=0.8 * warm_rps)

    merge_bench_json("gnn_serve", {
        "backends": list(backends), "plan": plan,
        "warm_requests": WARM_REQUESTS, "rows": rows, "poisson": poisson})
    ref_rows = [r for r in rows if r["backend"] == "reference"]
    derived = {"min_warm_rps": min(r["warm_req_per_s"]
                                   for r in (ref_rows or rows)),
               "backends": "+".join(backends),
               "poisson_p99_ms": poisson["p99_ms"] if poisson else None,
               "poisson_peak_queue": (poisson["peak_queue_depth"]
                                      if poisson else None),
               "recorded": "BENCH_gnn.json"}
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default=",".join(DEFAULT_BACKENDS),
                    help="comma list of kernel backends to sweep")
    ap.add_argument("--plan", choices=["analytic", "autotune"],
                    default="analytic",
                    help="plan source for non-reference backends")
    ap.add_argument("--tune-budget", type=int, default=4)
    args = ap.parse_args()

    from repro import env
    env.pin_for_benchmarks()
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    rows, derived = bench_gnn_serve(backends=backends, plan=args.plan,
                                    tune_budget=args.tune_budget)
    for r in rows:
        print(r)
    print(derived)


if __name__ == "__main__":
    main()

"""GNN serving benchmark: requests/sec of serving/gnn_engine.py across the
three Table-II citation graphs, recorded to BENCH_gnn.json.

Two regimes per graph:
  * cold  — first request per (model, graph): compiles the Executable
            (plan + shard + jit) and runs full-graph inference (the
            amortized unit of work).
  * warm  — steady-state request stream answered from the Executable's
            cached full-graph softmax (GNNIE's \"accelerator wins become
            end-user wins\" path).

Runs on the reference backend (pure jnp) so the numbers measure the
serving stack, not Pallas interpret-mode overhead; pubmed is scaled down
to keep the densified shard grid within CPU memory.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.report import merge_bench_json

# (name, scale): pubmed's densified (S·n)² grid at full scale is ~1.5 GiB,
# too big for a CPU smoke benchmark.
GRAPHS = (("cora", 1.0), ("citeseer", 1.0), ("pubmed", 0.15))
WARM_REQUESTS = 256
BACKEND = "reference"


def bench_gnn_serve():
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

    rows = []
    for name, scale in GRAPHS:
        ds = make_dataset(name, seed=0, scale=scale)
        prof = ds.profile
        engine = GNNServeEngine(max_shard_n=512, backend=BACKEND)
        engine.register_graph(name, ds)
        engine.register_model("gcn", ZooSpec("gcn", prof.feature_dim, 16,
                                             prof.num_classes, num_layers=2))

        rng = np.random.default_rng(0)

        def req():
            ids = rng.integers(0, prof.num_nodes, size=8)
            return NodeRequest(name, ids, model="gcn")

        t0 = time.perf_counter()
        engine.serve([req()])
        cold_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        engine.serve([req() for _ in range(WARM_REQUESTS)])
        warm_s = time.perf_counter() - t0
        warm_rps = WARM_REQUESTS / warm_s

        s = engine.stats
        rows.append({
            "graph": prof.name, "nodes": prof.num_nodes,
            "edges": int(ds.edges.shape[0]), "scale": scale,
            "cold_ms": round(cold_s * 1e3, 2),
            "warm_req_per_s": round(warm_rps, 1),
            "logits_cache_hits": s["logits_cache_hits"],
            "logits_cache_misses": s["logits_cache_misses"],
        })

    merge_bench_json("gnn_serve", {
        "backend": BACKEND, "warm_requests": WARM_REQUESTS, "rows": rows})
    derived = {"min_warm_rps": min(r["warm_req_per_s"] for r in rows),
               "recorded": "BENCH_gnn.json"}
    return rows, derived

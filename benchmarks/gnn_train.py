"""GNN training benchmark: step time, steps-to-accuracy, and hot-reload
latency into serving, recorded to BENCH_gnn.json (`gnn_train` section).

Three measurements:

  * **train** — full-batch `runtime.fit` training on cora/citeseer
    (reference backend, so the numbers measure the training stack, not
    Pallas interpret-mode overhead): mean/median step wall time after the
    first traced step, and the first step reaching the target train
    accuracy (the tier-1 acceptance threshold, 0.75).
  * **minibatch** — neighbor-sampled steps on cora (fixed-budget
    subgraphs, one jit trace): mean step time including the numpy
    sample+shard work, for comparison against the full-batch step.
  * **reload** — serving-side weight swap: ms to hot-reload trained
    params into a compiled Executable through ``Server.reload`` (no
    recompile), the first post-reload request (pays one full-graph
    softmax recompute), and a warm request after it.

    PYTHONPATH=src python -m benchmarks.gnn_train
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.report import merge_bench_json

TRAIN_GRAPHS = ("cora", "citeseer")
ARCH = "gcn"
STEPS = 200
TARGET_ACC = 0.75
BACKEND = "reference"
MINIBATCH_STEPS = 30


def _trainable(ds, *, batch_nodes=0, fanout=(10, 5)):
    from repro import runtime
    from repro.gnn.models import ZooSpec
    from repro.graphs.sampler import NeighborSampler
    from repro.runtime.fit import TrainableExecutable
    from repro.training.optimizer import AdamWConfig

    spec = ZooSpec(ARCH, ds.profile.feature_dim, 16, ds.profile.num_classes)
    exe = runtime.compile(spec, ds, backend=BACKEND)
    sampler = None
    if batch_nodes:
        sampler = NeighborSampler(ds.edges, ds.profile.num_nodes,
                                  batch_nodes=batch_nodes, fanout=fanout,
                                  seed_ids=np.flatnonzero(ds.train_mask))
    opt = AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=0.0,
                      schedule="constant", warmup_steps=0)
    return TrainableExecutable(exe, ds.labels, train_mask=ds.train_mask,
                               features=ds.features, opt_cfg=opt,
                               sampler=sampler)


def _run_steps(tr, steps: int):
    """Manual loop (instead of TrainLoop) so every step is timed and the
    per-step train accuracy is visible for steps-to-target."""
    params, opt = tr.params, tr.opt_state
    step_ms, accs = [], []
    for step in range(steps):
        batch = tr.data(step)
        t0 = time.perf_counter()
        params, opt, metrics = tr.step_fn(params, opt, batch)
        acc = float(metrics["acc"])
        step_ms.append((time.perf_counter() - t0) * 1e3)
        accs.append(acc)
    tr.params, tr.opt_state = params, opt
    tr.executable.update_params(params)
    return step_ms, accs


def bench_training() -> dict:
    from repro.graphs.datasets import make_dataset

    out = {}
    for name in TRAIN_GRAPHS:
        ds = make_dataset(name, seed=0)
        tr = _trainable(ds)
        step_ms, accs = _run_steps(tr, STEPS)
        to_target = next((i for i, a in enumerate(accs) if a >= TARGET_ACC),
                         None)
        warm = step_ms[1:]   # step 0 pays the jit trace
        out[name] = {
            "arch": ARCH,
            "steps": STEPS,
            "trace_step_ms": round(step_ms[0], 3),
            "mean_step_ms": round(float(np.mean(warm)), 3),
            "p50_step_ms": round(float(np.median(warm)), 3),
            "final_train_acc": round(accs[-1], 4),
            "steps_to_target_acc": to_target,
            "target_acc": TARGET_ACC,
        }
        print(f"[train] {name}: {out[name]['mean_step_ms']:.1f} ms/step, "
              f"acc {accs[-1]:.3f}, {to_target} steps to {TARGET_ACC}")
    return out


def bench_minibatch() -> dict:
    from repro.graphs.datasets import make_dataset

    ds = make_dataset("cora", seed=0)
    tr = _trainable(ds, batch_nodes=256, fanout=(10, 5))
    step_ms, accs = _run_steps(tr, MINIBATCH_STEPS)
    out = {
        "arch": ARCH, "batch_nodes": 256, "fanout": [10, 5],
        "steps": MINIBATCH_STEPS,
        "trace_step_ms": round(step_ms[0], 3),
        "mean_step_ms": round(float(np.mean(step_ms[1:])), 3),
        "final_batch_acc": round(accs[-1], 4),
    }
    print(f"[minibatch] cora: {out['mean_step_ms']:.1f} ms/step "
          f"(sample+shard+update)")
    return out


def bench_reload() -> dict:
    """Weight-swap latency through the serving stack."""
    import jax

    from repro.gnn.models import ZooSpec, init_zoo
    from repro.graphs.datasets import make_dataset
    from repro.serving import Completed, SchedulerConfig, Server
    from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

    ds = make_dataset("cora", seed=0)
    spec = ZooSpec(ARCH, ds.profile.feature_dim, 16, ds.profile.num_classes)
    engine = GNNServeEngine(backend=BACKEND)
    engine.register_graph("cora", ds)
    engine.register_model("gcn", spec, seed=0)
    server = Server(engine, SchedulerConfig(max_batch_size=8))

    def one_request() -> float:
        t = server.submit(NodeRequest("cora", np.arange(8), model="gcn"))
        t0 = time.perf_counter()
        server.drain()
        ms = (time.perf_counter() - t0) * 1e3
        assert isinstance(t.result(), Completed)
        return ms

    cold_ms = one_request()
    warm_ms = float(np.median([one_request() for _ in range(5)]))

    new_params = init_zoo(jax.random.key(1), spec)
    t0 = time.perf_counter()
    server.reload(lambda eng: eng.reload_params("gcn", new_params))
    reload_ms = (time.perf_counter() - t0) * 1e3
    post_reload_ms = one_request()       # pays the softmax recompute
    rewarm_ms = float(np.median([one_request() for _ in range(5)]))

    out = {
        "cold_request_ms": round(cold_ms, 3),
        "warm_request_ms": round(warm_ms, 3),
        "reload_ms": round(reload_ms, 3),
        "first_post_reload_request_ms": round(post_reload_ms, 3),
        "warm_post_reload_request_ms": round(rewarm_ms, 3),
        "compiles": engine.stats["compiles"],
        "logits_invalidations": engine.stats["logits_invalidations"],
    }
    print(f"[reload] swap {reload_ms:.2f} ms, first post-reload request "
          f"{post_reload_ms:.1f} ms (softmax recompute), warm "
          f"{rewarm_ms:.2f} ms; {out['compiles']} compile(s) total")
    return out


def main() -> None:
    payload = {
        "backend": BACKEND,
        "train": bench_training(),
        "minibatch": bench_minibatch(),
        "reload": bench_reload(),
    }
    merge_bench_json("gnn_train", payload)
    print("wrote gnn_train section to BENCH_gnn.json")


if __name__ == "__main__":
    main()

"""GNN training benchmark: step time, steps-to-accuracy, and hot-reload
latency into serving, recorded to BENCH_gnn.json (`gnn_train` section).

Three measurements:

  * **train** — full-batch `runtime.fit` training, one row per
    (graph, backend): mean/median step wall time after the first traced
    step, and the first step reaching the target train accuracy (the
    tier-1 acceptance threshold, 0.75). Reference rows run the Table-II
    graphs at full scale; pallas rows run cora scaled down (interpret
    mode off-TPU pays a large per-element cost) for a reduced step
    count, with the layer plan optionally autotuned (``--plan``).
  * **minibatch** — neighbor-sampled steps on cora (fixed-budget
    subgraphs, one jit trace): mean step time including the numpy
    sample+shard work, for comparison against the full-batch step.
  * **reload** — serving-side weight swap: ms to hot-reload trained
    params into a compiled Executable through ``Server.reload`` (no
    recompile), the first post-reload request (pays one full-graph
    softmax recompute), and a warm request after it.

    PYTHONPATH=src python -m benchmarks.gnn_train \
        --backends reference,pallas --plan autotune
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.report import merge_bench_json

# (graph, scale, steps) per backend
TRAIN_GRAPHS = {
    "reference": (("cora", 1.0, 200), ("citeseer", 1.0, 200)),
    "pallas": (("cora", 0.25, 8),),
}
SHARD_N = {"reference": 512, "pallas": 256}
ARCH = "gcn"
TARGET_ACC = 0.75
DEFAULT_BACKENDS = ("reference", "pallas")
MINIBATCH_STEPS = 30


def _trainable(ds, *, backend="reference", plan="analytic", tune_budget=4,
               max_shard_n=512, batch_nodes=0, fanout=(10, 5)):
    from repro import runtime
    from repro.gnn.models import ZooSpec
    from repro.graphs.sampler import NeighborSampler
    from repro.runtime.fit import TrainableExecutable
    from repro.training.optimizer import AdamWConfig

    spec = ZooSpec(ARCH, ds.profile.feature_dim, 16, ds.profile.num_classes)
    exe = runtime.compile(spec, ds, backend=backend, plan=plan,
                          tune_budget=tune_budget, max_shard_n=max_shard_n)
    sampler = None
    if batch_nodes:
        sampler = NeighborSampler(ds.edges, ds.profile.num_nodes,
                                  batch_nodes=batch_nodes, fanout=fanout,
                                  seed_ids=np.flatnonzero(ds.train_mask))
    opt = AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=0.0,
                      schedule="constant", warmup_steps=0)
    return TrainableExecutable(exe, ds.labels, train_mask=ds.train_mask,
                               features=ds.features, opt_cfg=opt,
                               sampler=sampler)


def _run_steps(tr, steps: int):
    """Manual loop (instead of TrainLoop) so every step is timed and the
    per-step train accuracy is visible for steps-to-target."""
    params, opt = tr.params, tr.opt_state
    step_ms, accs = [], []
    for step in range(steps):
        batch = tr.data(step)
        t0 = time.perf_counter()
        params, opt, metrics = tr.step_fn(params, opt, batch)
        acc = float(metrics["acc"])
        step_ms.append((time.perf_counter() - t0) * 1e3)
        accs.append(acc)
    tr.params, tr.opt_state = params, opt
    tr.executable.update_params(params)
    return step_ms, accs


def bench_training(backends=DEFAULT_BACKENDS, plan="analytic",
                   tune_budget=4) -> list:
    from repro.graphs.datasets import make_dataset

    rows = []
    for backend in backends:
        be_plan = plan if backend != "reference" else "analytic"
        for name, scale, steps in TRAIN_GRAPHS[backend]:
            ds = make_dataset(name, seed=0, scale=scale)
            tr = _trainable(ds, backend=backend, plan=be_plan,
                            tune_budget=tune_budget,
                            max_shard_n=SHARD_N[backend])
            step_ms, accs = _run_steps(tr, steps)
            to_target = next((i for i, a in enumerate(accs)
                              if a >= TARGET_ACC), None)
            warm = step_ms[1:]   # step 0 pays the jit trace
            row = {
                "graph": ds.profile.name, "arch": ARCH, "backend": backend,
                "plan_source": tr.executable.plan_source, "scale": scale,
                "steps": steps,
                "trace_step_ms": round(step_ms[0], 3),
                "mean_step_ms": round(float(np.mean(warm)), 3),
                "p50_step_ms": round(float(np.median(warm)), 3),
                "final_train_acc": round(accs[-1], 4),
                "steps_to_target_acc": to_target,
                "target_acc": TARGET_ACC,
            }
            rows.append(row)
            print(f"[train] {row['graph']} ({backend}/{row['plan_source']}): "
                  f"{row['mean_step_ms']:.1f} ms/step, acc {accs[-1]:.3f}, "
                  f"{to_target} steps to {TARGET_ACC}")
    return rows


def bench_minibatch() -> dict:
    from repro.graphs.datasets import make_dataset

    ds = make_dataset("cora", seed=0)
    tr = _trainable(ds, batch_nodes=256, fanout=(10, 5))
    step_ms, accs = _run_steps(tr, MINIBATCH_STEPS)
    out = {
        "arch": ARCH, "backend": "reference", "plan_source": "analytic",
        "batch_nodes": 256, "fanout": [10, 5],
        "steps": MINIBATCH_STEPS,
        "trace_step_ms": round(step_ms[0], 3),
        "mean_step_ms": round(float(np.mean(step_ms[1:])), 3),
        "final_batch_acc": round(accs[-1], 4),
    }
    print(f"[minibatch] cora: {out['mean_step_ms']:.1f} ms/step "
          f"(sample+shard+update)")
    return out


def bench_reload() -> dict:
    """Weight-swap latency through the serving stack."""
    import jax

    from repro.gnn.models import ZooSpec, init_zoo
    from repro.graphs.datasets import make_dataset
    from repro.serving import Completed, SchedulerConfig, Server
    from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

    ds = make_dataset("cora", seed=0)
    spec = ZooSpec(ARCH, ds.profile.feature_dim, 16, ds.profile.num_classes)
    engine = GNNServeEngine(backend="reference")
    engine.register_graph("cora", ds)
    engine.register_model("gcn", spec, seed=0)
    server = Server(engine, SchedulerConfig(max_batch_size=8))

    def one_request() -> float:
        t = server.submit(NodeRequest("cora", np.arange(8), model="gcn"))
        t0 = time.perf_counter()
        server.drain()
        ms = (time.perf_counter() - t0) * 1e3
        assert isinstance(t.result(), Completed)
        return ms

    cold_ms = one_request()
    warm_ms = float(np.median([one_request() for _ in range(5)]))

    new_params = init_zoo(jax.random.key(1), spec)
    t0 = time.perf_counter()
    server.reload(lambda eng: eng.reload_params("gcn", new_params))
    reload_ms = (time.perf_counter() - t0) * 1e3
    post_reload_ms = one_request()       # pays the softmax recompute
    rewarm_ms = float(np.median([one_request() for _ in range(5)]))

    out = {
        "backend": "reference", "plan_source": "analytic",
        "cold_request_ms": round(cold_ms, 3),
        "warm_request_ms": round(warm_ms, 3),
        "reload_ms": round(reload_ms, 3),
        "first_post_reload_request_ms": round(post_reload_ms, 3),
        "warm_post_reload_request_ms": round(rewarm_ms, 3),
        "compiles": engine.stats["compiles"],
        "logits_invalidations": engine.stats["logits_invalidations"],
    }
    print(f"[reload] swap {reload_ms:.2f} ms, first post-reload request "
          f"{post_reload_ms:.1f} ms (softmax recompute), warm "
          f"{rewarm_ms:.2f} ms; {out['compiles']} compile(s) total")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default=",".join(DEFAULT_BACKENDS),
                    help="comma list of kernel backends to sweep")
    ap.add_argument("--plan", choices=["analytic", "autotune"],
                    default="analytic",
                    help="plan source for non-reference backends")
    ap.add_argument("--tune-budget", type=int, default=4)
    args = ap.parse_args()

    from repro import env
    env.pin_for_benchmarks()
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    payload = {
        "train": bench_training(backends=backends, plan=args.plan,
                                tune_budget=args.tune_budget),
        "minibatch": bench_minibatch(),
        "reload": bench_reload(),
    }
    merge_bench_json("gnn_train", payload)
    print("wrote gnn_train section to BENCH_gnn.json")


if __name__ == "__main__":
    main()

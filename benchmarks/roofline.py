"""Roofline analysis over the dry-run artifacts (deliverable g).

For every (arch × shape × mesh) JSON produced by launch/dryrun.py, derive
the three roofline terms on TPU v5e:

    compute_s    = HLO_FLOPs_per_device   / 197e12   (bf16 peak per chip)
    memory_s     = HLO_bytes_per_device   / 819e9    (HBM bandwidth)
    collective_s = wire_bytes_per_device  / 50e9     (one ICI link; v5e has
                   4 usable links — multi-link overlap is reported as
                   headroom, not assumed)

cost_analysis numbers are per-device (verified in DESIGN.md §5); wire
bytes are the bandwidth-adjusted per-device collective traffic from
dist/hlo_analysis.py. MODEL_FLOPS uses 6·N_active·T for training and
2·N_active·T for inference (T = tokens processed per step).
"""
from __future__ import annotations

import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

SUGGESTIONS = {
    "compute": "increase per-device arithmetic intensity (larger micro-batch"
               " or less remat recompute)",
    "memory": "cut HLO bytes: fuse elementwise chains, bf16 intermediates,"
              " avoid replicated activations",
    "collective": "reshard to remove per-layer all-gathers (kv/heads layout),"
                  " overlap collectives with compute, int8-compress DP grads",
}


def model_flops(rec: dict) -> float:
    tokens = rec["global_batch"] * (1 if rec["kind"] == "decode"
                                    else rec["seq_len"])
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * rec["params_active"] * tokens / max(rec.get("devices", 1), 1)


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    c = rec["costs"]
    compute_s = c["flops_per_device"] / PEAK_FLOPS
    memory_s = c["bytes_accessed_per_device"] / HBM_BW
    coll_s = c["collectives"]["total_wire_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    mem = rec.get("proof", {}).get("memory", {}) or {}
    hbm_gib = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
               - mem.get("alias_bytes", 0)) / 2 ** 30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "roofline_frac": compute_s / max(max(terms.values()), 1e-30),
        "model_flops_per_dev": mf,
        "useful_flop_frac": mf / max(c["flops_per_device"], 1e-30),
        "mem_gib_per_dev": hbm_gib,
        "suggestion": SUGGESTIONS[dominant],
    }


def load_all(dry_dir: str = "results/dryrun") -> list[dict]:
    out = []
    for p in sorted(pathlib.Path(dry_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag"):
            continue
        a = analyze_record(rec)
        if a is None and rec.get("status") == "skipped":
            a = {"arch": rec["arch"], "shape": rec["shape"],
                 "mesh": rec["mesh"], "skipped": rec.get("reason", "")}
        if a is not None:
            out.append(a)
    return out


def markdown_table(rows: list[dict], mesh: str = "single") -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | roofline frac | useful-FLOP frac | HBM GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['roofline_frac']:.2f} | "
            f"{r['useful_flop_frac']:.2f} | {r['mem_gib_per_dev']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def bench_roofline(dry_dir: str = "results/dryrun"):
    rows = load_all(dry_dir)
    ok = [r for r in rows if "skipped" not in r]
    if not ok:
        return [], {"cells_analyzed": 0}
    import numpy as np
    fr = [r["roofline_frac"] for r in ok]
    dom = {}
    for r in ok:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    return rows, {
        "cells_analyzed": len(ok),
        "median_roofline_frac": round(float(np.median(fr)), 3),
        "dominant_counts": dom,
    }

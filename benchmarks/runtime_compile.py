"""runtime.compile() benchmark: cold vs cached compile latency.

Cold = first compile of a (spec, graph) pair in the process: layer
planning, graph sharding + normalization baking, param init, jit setup.
Cached = recompile of the same pair: the content-hash plan memo and the
signature-keyed GraphStore both hit, so only param init + jit setup
remain. Recorded to BENCH_gnn.json with the plan-cache hit rate.
"""
from __future__ import annotations

import time

from benchmarks.report import merge_bench_json

GRAPHS = (("cora", 0.5), ("citeseer", 0.5))
ARCHS = ("gcn", "gat")
BACKEND = "reference"


def bench_runtime_compile():
    from repro import runtime
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset

    runtime.clear_plan_cache()
    store = runtime.GraphStore(max_entries=16)
    rows = []
    for name, scale in GRAPHS:
        ds = make_dataset(name, seed=0, scale=scale)
        prof = ds.profile
        for arch in ARCHS:
            spec = ZooSpec(arch, prof.feature_dim, 16, prof.num_classes,
                           num_layers=2, heads=2)

            t0 = time.perf_counter()
            exe = runtime.compile(spec, ds, backend=BACKEND, store=store,
                                  graph_key=name, max_shard_n=512)
            cold_ms = (time.perf_counter() - t0) * 1e3

            t0 = time.perf_counter()
            runtime.compile(spec, ds, backend=BACKEND, store=store,
                            graph_key=name, max_shard_n=512)
            cached_ms = (time.perf_counter() - t0) * 1e3

            rows.append({
                "graph": prof.name, "arch": arch, "backend": BACKEND,
                "plan_source": exe.plan_source, "scale": scale,
                "shard_n": exe.plan.shard_n,
                "cold_compile_ms": round(cold_ms, 2),
                "cached_compile_ms": round(cached_ms, 2),
                "speedup": round(cold_ms / max(cached_ms, 1e-6), 1),
            })

    stats = runtime.plan_cache_stats()
    tot = stats["hits"] + stats["misses"] + stats["disk_hits"]
    hit_rate = (stats["hits"] + stats["disk_hits"]) / max(tot, 1)
    graph_stats = store.stats

    merge_bench_json("runtime_compile", {
        "backend": BACKEND, "rows": rows,
        "plan_cache": {**stats, "hit_rate": round(hit_rate, 3)},
        "graph_store": graph_stats,
    })
    derived = {"plan_cache_hit_rate": round(hit_rate, 3),
               "max_cached_speedup": max(r["speedup"] for r in rows),
               "recorded": "BENCH_gnn.json"}
    return rows, derived

"""Paper-table reproductions (one function per table/figure).

Each function returns (rows, derived) where rows are CSV-printable dicts
and derived is the headline number compared against the paper's claim.
"""
from __future__ import annotations

import numpy as np

from repro.core.dataflow import Dataflow, best_order, simulate_traffic, table1_costs
from repro.core.perf_model import (GNNERATOR, GNNERATOR_NOBLOCK, GPU_2080TI,
                                   HYGCN, model_time, speedup_table)
from repro.graphs.datasets import TABLE2_DATASETS as DATASETS


def bench_table1():
    """Table I: analytical read/write costs vs simulated schedule traffic.

    The simulator counts actual shard-feature loads for an S-pattern
    schedule; the analytic formulas must match within one boundary term.
    """
    rows = []
    max_rel = 0.0
    for s in (2, 4, 8, 16):
        for order in ("src_stationary", "dst_stationary"):
            costs = table1_costs(s, I=1.0)[order]
            df = Dataflow(S=s, D=64, B=64, order=order)
            tr = simulate_traffic(df, nodes_per_shard=1, edges_per_shard=1.0,
                                  dtype_bytes=1, skip_empty=False)
            sim_reads = tr.offchip_read_bytes / 64      # per-dim units
            sim_writes = tr.offchip_write_bytes / 64
            rel = abs(sim_reads - costs["read"]) / max(costs["read"], 1)
            max_rel = max(max_rel, rel)
            rows.append({
                "S": s, "order": order,
                "analytic_read": costs["read"], "sim_read": sim_reads,
                "analytic_write": costs["write"], "sim_write": sim_writes,
                "best_order": best_order(s),
            })
    return rows, {"max_read_rel_err": round(max_rel, 3)}


def bench_fig3():
    """Fig 3: speedup vs RTX 2080 Ti across the 9 benchmarks.

    Paper: 8.0x average with dimension-blocking, 4.2x without.
    """
    table = speedup_table(block_b=64)
    rows = []
    for key, r in table.items():
        rows.append({"benchmark": key,
                     "speedup_blocked": round(r["gnnerator"], 2),
                     "speedup_noblock": round(r["gnnerator_noblock"], 2),
                     "hygcn": round(r["hygcn"], 2)})
    avg_b = float(np.mean([r["gnnerator"] for r in table.values()]))
    avg_n = float(np.mean([r["gnnerator_noblock"] for r in table.values()]))
    return rows, {
        "avg_speedup_blocked": round(avg_b, 2), "paper_blocked": 8.0,
        "avg_speedup_noblock": round(avg_n, 2), "paper_noblock": 4.2,
        "blocking_gain": round(avg_b / avg_n, 2),
        "paper_blocking_gain": round(8.0 / 4.2, 2),
    }


def bench_table5():
    """Table V: GNNerator speedup over HyGCN for GCN.

    Paper (blocked): cora 3.8x, citeseer 3.2x, pubmed 2.3x (avg 3.15x over
    all networks). HyGCN's sparsity-elimination (orthogonal, see §VI-A) is
    applied as the paper states: ~1.1x cora/pubmed, ~3x citeseer.
    """
    sparsity_elim = {"cora": 1.1, "citeseer": 3.0, "pubmed": 1.1}
    paper = {"cora": 3.8, "citeseer": 3.2, "pubmed": 2.3}
    paper_nb = {"cora": 1.8, "citeseer": 0.8, "pubmed": 1.0}
    rows = []
    for ds in DATASETS:
        t_hygcn = model_time(HYGCN, "gcn", ds,
                             sparsity_elim=sparsity_elim[ds])
        t_blk = model_time(GNNERATOR, "gcn", ds, block_b=64)
        t_nb = model_time(GNNERATOR_NOBLOCK, "gcn", ds)
        rows.append({
            "dataset": ds,
            "vs_hygcn_blocked": round(t_hygcn / t_blk, 2),
            "paper_blocked": paper[ds],
            "vs_hygcn_noblock": round(t_hygcn / t_nb, 2),
            "paper_noblock": paper_nb[ds],
        })
    avg = float(np.mean([r["vs_hygcn_blocked"] for r in rows]))
    return rows, {"avg_vs_hygcn": round(avg, 2), "paper_avg": 3.15}


def bench_fig4():
    """Fig 4: feature-block-size sweep. Paper: smaller B is better until
    B < dense-engine width (64), where utilization collapses."""
    rows = []
    for b in (16, 32, 64, 128, 256, 512):
        speeds = []
        for net in ("gcn", "graphsage", "graphsage_pool"):
            for ds in DATASETS:
                t_gpu = model_time(GPU_2080TI, net, ds)
                speeds.append(t_gpu / model_time(GNNERATOR, net, ds, block_b=b))
        rows.append({"B": b, "avg_speedup": round(float(np.mean(speeds)), 2)})
    best = max(rows, key=lambda r: r["avg_speedup"])["B"]
    return rows, {"best_B": best, "paper_best_B": 64}


def bench_fig5():
    """Fig 5: where to invest 2x hardware. Paper: bandwidth helps small
    hidden sizes; a bigger Dense Engine wins at large hidden sizes."""
    import dataclasses
    variants = {
        "2x_graph_mem": dataclasses.replace(GNNERATOR, onchip_graph_mb=48.0),
        "2x_dense": dataclasses.replace(GNNERATOR, dense_tflops=32.0,
                                        dense_width=128),
        "2x_bw": dataclasses.replace(GNNERATOR, dram_gbs=512.0),
    }
    rows = []
    winners = {}
    for hidden in (16, 64, 128, 256, 512, 1024):
        base = np.mean([model_time(GNNERATOR, "gcn", ds, hidden=hidden,
                                   depth=3) for ds in DATASETS])
        row = {"hidden": hidden}
        for name, plat in variants.items():
            t = np.mean([model_time(plat, "gcn", ds, hidden=hidden, depth=3)
                         for ds in DATASETS])
            row[name] = round(float(base / t), 3)
        winners[hidden] = max(variants, key=lambda nm: row[nm])
        rows.append(row)
    return rows, {
        "winner_small_hidden": winners[16],
        "winner_large_hidden": winners[1024],
        "paper": "bw wins small hidden; dense engine wins large hidden",
    }

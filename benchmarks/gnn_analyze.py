"""Static-analysis benchmark: what the analyzer costs, and what its
pruning saves, recorded to BENCH_gnn.json (`analyze` section).

Two measurements:

  * **gate** — wall time of every ``repro.analyze`` pass exactly as the
    CI gate runs them (``launch.analyze.build_report`` with the dynamic
    retrace probes on), per-pass and total, plus the finding counts
    (which must be zero on a healthy checkout).
  * **autotune_pruning** — one real autotune run on a Table-II graph:
    candidates measured vs statically pruned, the mean measure cost per
    candidate, and the estimated measure time the pruning saved
    (pruned candidates are execution-identical or illegal, so each one
    skipped is one full compile+measure loop that was never paid).

    PYTHONPATH=src python -m benchmarks.gnn_analyze --budget 6
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.report import merge_bench_json

GRAPH, SCALE = "cora", 0.25
ARCH = "gcn"
BUDGET = 6
REPS = 3
MAX_SHARD_N = 128


def bench_analyze(budget: int = BUDGET, reps: int = REPS) -> dict:
    from repro import runtime, tune
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.kernels.registry import resolve
    from repro.launch.analyze import build_report

    # -- the CI gate's cost on this checkout -------------------------------
    t0 = time.perf_counter()
    report = build_report(probe=True)
    gate_s = time.perf_counter() - t0
    gate = {
        "total_s": round(gate_s, 3),
        "pass_ms": {k: round(v, 1) for k, v in report.timings_ms.items()},
        "findings": {s: report.count(s)
                     for s in ("error", "warning", "info")},
        "skipped": sorted(report.skipped),
    }

    # -- measure time saved by static pruning ------------------------------
    runtime.clear_tune_cache()
    ds = make_dataset(GRAPH, seed=0, scale=SCALE)
    spec = ZooSpec(ARCH, ds.profile.feature_dim, 16, ds.profile.num_classes,
                   num_layers=2)
    t0 = time.perf_counter()
    rec = tune.autotune_plan(spec, ds.edges, ds.profile.num_nodes,
                             backend=resolve(None, "reference"),
                             features=ds.features, max_n=MAX_SHARD_N,
                             budget=budget, reps=reps)
    tune_s = time.perf_counter() - t0
    rep = rec.report()
    measured = rep["candidates_measured"]
    per_candidate_s = tune_s / max(measured, 1)
    pruning = {
        "graph": GRAPH, "scale": SCALE, "arch": ARCH,
        "budget": budget, "reps": reps,
        "tune_s": round(tune_s, 3),
        "candidates_measured": measured,
        "candidates_failed": rep["candidates_failed"],
        "candidates_pruned": rep["candidates_pruned"],
        "pruned_reasons": rep["pruned_reasons"],
        "per_candidate_s": round(per_candidate_s, 3),
        "est_measure_time_saved_s":
            round(rep["candidates_pruned"] * per_candidate_s, 3),
    }

    payload = {"gate": gate, "autotune_pruning": pruning}
    merge_bench_json("analyze", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--budget", type=int, default=BUDGET)
    ap.add_argument("--reps", type=int, default=REPS)
    args = ap.parse_args()
    print(json.dumps(bench_analyze(args.budget, args.reps), indent=2))


if __name__ == "__main__":
    main()

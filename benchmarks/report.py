"""Generate EXPERIMENTS.md §Dry-run and §Roofline from results/dryrun JSONs.

    PYTHONPATH=src python -m benchmarks.report [--dry-dir results/dryrun]

Prints the markdown to stdout; the checked-in EXPERIMENTS.md embeds the
output (regenerate after hillclimb iterations).
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.roofline import analyze_record, markdown_table

BENCH_GNN_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_gnn.json"


def merge_bench_json(section: str, payload: dict,
                     path: pathlib.Path = BENCH_GNN_PATH) -> None:
    """Read-modify-write one named section of BENCH_gnn.json so the GNN
    benchmarks (gnn_serve, runtime_compile, ...) can each record results
    without clobbering the others."""
    doc = {}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except ValueError:
            doc = {}
    if "benchmark" in doc:       # pre-PR-2 single-benchmark layout
        doc = {doc.pop("benchmark", "gnn_serve"): doc}
    doc[section] = payload
    path.write_text(json.dumps(doc, indent=2) + "\n")


def dryrun_table(dry_dir: str, mesh: str) -> str:
    rows = []
    for p in sorted(pathlib.Path(dry_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag") or rec.get("mesh") != mesh:
            continue
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | skipped | — | — "
                        f"| — | — | {rec['reason'][:46]} |")
            continue
        if rec["status"] == "error":
            rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | — | — "
                        f"| — | — | {rec.get('error', '')[:46]} |")
            continue
        c, pr = rec["costs"], rec["proof"]
        mem = pr.get("memory", {})
        mem_gib = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
                   - mem.get("alias_bytes", 0)) / 2 ** 30
        coll = c["collectives"]
        dominant_coll = max(coll["wire_bytes"], key=coll["wire_bytes"].get) \
            if coll["wire_bytes"] else "none"
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | ok | "
            f"{c['flops_per_device']:.2e} | "
            f"{c['bytes_accessed_per_device']:.2e} | "
            f"{coll['total_wire_bytes'] / 2**30:.1f} | {mem_gib:.1f} | "
            f"{dominant_coll} ({sum(coll['counts'].values()):.0f} ops) |")
    hdr = ("| arch | shape | status | HLO FLOPs/dev | HLO bytes/dev | "
           "collective GiB/dev | HBM GiB/dev | dominant collective |\n"
           "|---|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join(rows) + "\n"


def roofline_rows(dry_dir: str) -> list[dict]:
    rows = []
    for p in sorted(pathlib.Path(dry_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag"):
            continue
        a = analyze_record(rec)
        if a is None and rec.get("status") == "skipped":
            a = {"arch": rec["arch"], "shape": rec["shape"],
                 "mesh": rec["mesh"], "skipped": rec.get("reason", "")}
        if a is not None:
            rows.append(a)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-dir", default="results/dryrun")
    args = ap.parse_args()
    for mesh in ("single", "multi"):
        print(f"\n### Dry-run — {mesh} pod mesh\n")
        print(dryrun_table(args.dry_dir, mesh))
    rows = roofline_rows(args.dry_dir)
    print("\n### Roofline — single pod (16×16)\n")
    print(markdown_table(rows, "single"))
    print("\n### Roofline — multi pod (2×16×16)\n")
    print(markdown_table(rows, "multi"))


if __name__ == "__main__":
    main()

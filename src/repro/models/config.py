"""Model configuration for the assigned architecture fleet.

A single ModelConfig describes every family we support (dense, MoE, VLM,
audio, hybrid, SSM) via a per-layer block pattern plus optional sub-configs.
The exact assigned configs live in src/repro/configs/<arch>.py.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

BlockKind = Literal["attn", "local_attn", "rglru", "mamba2"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    n_shared_experts: int = 0      # shared experts always applied (Qwen-MoE)
    d_ff_expert: int = 0           # routed expert hidden dim
    d_ff_shared: int = 0           # per-shared-expert hidden dim
    capacity_factor: float = 1.25
    router_softmax_topk: bool = True  # softmax over selected experts' logits


@dataclasses.dataclass(frozen=True)
class SSMConfig:               # Mamba2 / SSD
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:             # RecurrentGemma / Griffin
    lru_width: int = 0         # 0 -> d_model
    conv_width: int = 4
    c_exponent: float = 8.0    # a_t = exp(c * r_t * log_a)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    block_pattern: tuple[str, ...] = ()     # empty -> all "attn"
    mlp_kind: str = "swiglu"                # swiglu | geglu | gelu | none
    moe: MoEConfig | None = None
    moe_layer_step: int = 1                 # every k-th layer is MoE
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    rope_kind: str = "rope"                 # rope | mrope
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    local_window: int | None = None         # for local_attn layers
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    emb_scale: float = 1.0                  # MiniCPM scale_emb
    residual_scale: float = 1.0             # MiniCPM scale_depth / sqrt(L)
    logit_scale: float = 1.0                # MiniCPM d_model/dim_model_base etc.
    n_codebooks: int = 1                    # MusicGen EnCodec codebooks
    input_mode: str = "tokens"              # tokens | embeddings (VLM stub)
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # attention flop control: max kv-chunks for the statically unrolled
    # online-softmax loop (see nn/attention.py)
    attn_chunk_max: int = 8
    sub_quadratic: bool = False             # eligible for long_500k

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.block_pattern:
            assert len(self.block_pattern) == self.n_layers
            return self.block_pattern
        return ("attn",) * self.n_layers

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe_layer_step == self.moe_layer_step - 1)

    def num_params(self) -> int:
        """Analytic parameter count (total)."""
        d, dh = self.d_model, self.head_dim
        total = self.vocab_size * d * self.n_codebooks
        if not self.tie_embeddings:
            total += self.vocab_size * d * self.n_codebooks
        for i, kind in enumerate(self.pattern):
            total += d  # pre-norm scale
            if kind in ("attn", "local_attn"):
                total += d * self.n_heads * dh  # wq
                total += 2 * d * self.n_kv_heads * dh  # wk, wv
                total += self.n_heads * dh * d  # wo
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * dh
                if self.qk_norm:
                    total += 2 * dh
            elif kind == "mamba2":
                ssm = self.ssm
                d_in = ssm.expand * d
                nheads = d_in // ssm.head_dim
                conv_ch = d_in + 2 * ssm.n_groups * ssm.d_state
                total += d * (2 * d_in + 2 * ssm.n_groups * ssm.d_state + nheads)
                total += conv_ch * ssm.d_conv
                total += 3 * nheads  # A_log, D, dt_bias
                total += d_in  # gated norm
                total += d_in * d  # out_proj
            elif kind == "rglru":
                w = self.rglru.lru_width or d
                total += 2 * d * w + w * self.rglru.conv_width
                total += 2 * w * w + 2 * w  # gates a/x + biases
                total += w  # log-lambda
                total += w * d  # out proj
            if self._layer_has_mlp(i):
                total += d  # post-norm scale
                if self.is_moe_layer(i):
                    m = self.moe
                    total += d * m.num_experts  # router
                    total += m.num_experts * 3 * d * m.d_ff_expert
                    total += m.n_shared_experts * 3 * d * m.d_ff_shared
                else:
                    mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                    total += mult * d * self.d_ff
        total += d  # final norm
        return total

    def _layer_has_mlp(self, i: int) -> bool:
        if self.mlp_kind == "none":
            return False
        return self.pattern[i] != "mamba2"

    def active_params(self) -> int:
        """Parameters touched per token (MoE: only routed top-k active)."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        total = self.num_params()
        # subtract inactive routed experts
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        inactive = (m.num_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return total - n_moe_layers * inactive

"""Unified decoder LM covering the whole assigned fleet.

One structure function (`param_struct`) describes every architecture —
dense / MoE / VLM / audio / hybrid / SSM — via the config's per-layer block
pattern. Instantiated with different leaf constructors it yields real
params, ShapeDtypeStructs (dry-run) or logical-axis trees (sharding); see
nn/layers.py.

Entry points:
    init_params / abstract_params / param_axes
    forward(params, cfg, batch)               # (B,S) -> logits
    loss_fn(params, cfg, batch)               # next-token CE
    prefill(params, cfg, batch, max_len)      # -> (logits, caches)
    decode_step(params, cfg, batch, caches)   # one token + caches
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.attention import (attn_apply, attn_cache_struct, attn_decode,
                                attn_prefill_cache, attn_struct)
from repro.nn.layers import (abstract_leaf, axes_leaf, dense, init_leaf,
                             mlp_apply, mlp_struct, rms_norm)
from repro.nn.moe import moe_apply, moe_struct
from repro.nn.rglru import (rglru_apply, rglru_cache_struct, rglru_decode,
                            rglru_struct)
from repro.nn.ssd import (ssd_apply, ssd_cache_struct, ssd_decode,
                          ssd_prefill_cache, ssd_struct)

Constrain = Callable[[jax.Array, tuple], jax.Array]


def _noop_constrain(x, axes):
    return x


# ---------------------------------------------------------------------------
# Parameter structure
# ---------------------------------------------------------------------------

def _layer_struct(leaf, i: int, cfg: ModelConfig) -> dict:
    kind = cfg.pattern[i]
    pre = f"layers.{i}"
    p: dict[str, Any] = {"ln1": leaf(f"{pre}.ln1", (cfg.d_model,), ("embed",),
                                     init="zeros")}
    if kind in ("attn", "local_attn"):
        p["attn"] = attn_struct(leaf, f"{pre}.attn", cfg)
    elif kind == "rglru":
        p["mixer"] = rglru_struct(leaf, f"{pre}.rglru", cfg)
    elif kind == "mamba2":
        p["mixer"] = ssd_struct(leaf, f"{pre}.ssd", cfg)
    else:
        raise ValueError(kind)
    if cfg._layer_has_mlp(i):
        p["ln2"] = leaf(f"{pre}.ln2", (cfg.d_model,), ("embed",), init="zeros")
        if cfg.is_moe_layer(i):
            p["moe"] = moe_struct(leaf, f"{pre}.moe", cfg)
        else:
            p["mlp"] = mlp_struct(leaf, f"{pre}.mlp", cfg.d_model, cfg.d_ff,
                                  cfg.mlp_kind)
    return p


def param_struct(cfg: ModelConfig, leaf) -> dict:
    d, v, c = cfg.d_model, cfg.vocab_size, cfg.n_codebooks
    p: dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        if c == 1:
            p["embed"] = leaf("embed", (v, d), ("vocab", "embed"), init="embed")
        else:
            p["embed"] = leaf("embed", (c, v, d), ("codebooks", "vocab", "embed"),
                              init="embed")
    else:  # embeddings supplied by the (stubbed) modality frontend
        p["embed_proj"] = leaf("embed_proj", (d, d), ("embed_in", "embed"))
    p["layers"] = [_layer_struct(leaf, i, cfg) for i in range(cfg.n_layers)]
    p["final_norm"] = leaf("final_norm", (d,), ("embed",), init="zeros")
    if not cfg.tie_embeddings or cfg.input_mode != "tokens":
        if c == 1:
            p["lm_head"] = leaf("lm_head", (d, v), ("embed", "vocab"))
        else:
            p["lm_head"] = leaf("lm_head", (c, d, v), ("codebooks", "embed", "vocab"))
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    return param_struct(cfg, init_leaf(key, cfg.pdtype))


# ---------------------------------------------------------------------------
# Scanned (stacked-layer) variant — used for the full-depth dry-run PROOF
# compiles: XLA compiles the scan body once, so a 64-layer model compiles in
# seconds on this single-core container. Costs are NOT taken from this
# artifact (cost_analysis counts a while body once); see launch/dryrun.py.
# ---------------------------------------------------------------------------

def pattern_period(cfg: ModelConfig) -> int:
    pat = cfg.pattern
    for p in (1, 2, 3, 4, 6):
        if len(pat) >= p and all(pat[i] == pat[i % p] for i in range(len(pat))):
            return p
    return len(pat)


def stacked_abstract_layers(cfg: ModelConfig):
    """Returns (stacked_params, stacked_axes, trail_params, trail_axes).

    Layers are grouped by position within the repeating block pattern
    (period p); each group of n_full layers is stacked with a leading
    'layers' axis. L % p trailing layers stay unrolled.
    """
    from repro.nn.layers import Axes, abstract_leaf, axes_leaf
    p = pattern_period(cfg)
    L = cfg.n_layers
    nf = L // p
    a_leaf = abstract_leaf(cfg.pdtype)
    x_leaf = axes_leaf()
    abs_layers = [_layer_struct(a_leaf, i, cfg) for i in range(L)]
    ax_layers = [_layer_struct(x_leaf, i, cfg) for i in range(L)]
    stacked, stacked_ax = [], []
    for j in range(p):
        group = [abs_layers[j + k * p] for k in range(nf)]
        stacked.append(jax.tree.map(
            lambda *ls: jax.ShapeDtypeStruct((nf,) + ls[0].shape, ls[0].dtype),
            *group))
        stacked_ax.append(jax.tree.map(
            lambda ax: Axes(("layers",) + ax.names), ax_layers[j]))
    trail = abs_layers[nf * p:]
    trail_ax = ax_layers[nf * p:]
    return tuple(stacked), tuple(stacked_ax), trail, trail_ax


def forward_scanned(params, cfg: ModelConfig, batch, *,
                    constrain: Constrain = _noop_constrain,
                    remat: bool = False) -> jax.Array:
    """Forward with lax.scan over stacked layers. params:
    {"embed"/..., "stack": tuple(stacked trees), "trail": [layer trees],
     "final_norm", "lm_head"?}."""
    p = pattern_period(cfg)
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    positions = _positions(cfg, batch, b, s)

    def body(x, xs):
        for j in range(p):
            x = _layer_apply(xs[j], x, cfg, j, positions, constrain)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["stack"])
    nf = cfg.n_layers // p
    for t, lp in enumerate(params["trail"]):
        x = _layer_apply(lp, x, cfg, nf * p + t, positions, constrain)
    return _logits_out(params, cfg, x)


def scanned_abstract_params(cfg: ModelConfig):
    """(abstract_params, axes) for the scanned variant."""
    full = param_struct(cfg, abstract_leaf(cfg.pdtype))
    full_ax = param_struct(cfg, axes_leaf())
    stack, stack_ax, trail, trail_ax = stacked_abstract_layers(cfg)
    params = {k: v for k, v in full.items() if k != "layers"}
    axes = {k: v for k, v in full_ax.items() if k != "layers"}
    params["stack"], params["trail"] = stack, list(trail)
    axes["stack"], axes["trail"] = stack_ax, list(trail_ax)
    return params, axes


def loss_fn_scanned(params, cfg: ModelConfig, batch, *,
                    constrain: Constrain = _noop_constrain,
                    remat: bool = False) -> jax.Array:
    logits = forward_scanned(params, cfg, batch, constrain=constrain,
                             remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].clip(0).astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def abstract_params(cfg: ModelConfig) -> dict:
    return param_struct(cfg, abstract_leaf(cfg.pdtype))


def param_axes(cfg: ModelConfig) -> dict:
    return param_struct(cfg, axes_leaf())


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _embed_in(params, cfg: ModelConfig, batch) -> jax.Array:
    if cfg.input_mode == "embeddings":
        x = batch["embeddings"].astype(cfg.cdtype)
        return dense(x, params["embed_proj"])
    toks = batch["tokens"]
    if cfg.n_codebooks == 1:
        x = params["embed"][toks]
    else:  # MusicGen: sum codebook embeddings; toks (B,S,C)
        x = sum(params["embed"][c][toks[..., c]] for c in range(cfg.n_codebooks))
    return x.astype(cfg.cdtype) * cfg.emb_scale


def _logits_out(params, cfg: ModelConfig, x) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        if cfg.n_codebooks == 1:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
        else:
            logits = jnp.einsum("bsd,cvd->bscv", x, params["embed"].astype(x.dtype))
    else:
        head = params["lm_head"].astype(x.dtype)
        if cfg.n_codebooks == 1:
            logits = jnp.einsum("bsd,dv->bsv", x, head)
        else:
            logits = jnp.einsum("bsd,cdv->bscv", x, head)
    return logits * cfg.logit_scale


def _positions(cfg: ModelConfig, batch, b: int, s: int):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.rope_kind == "mrope":
        return jnp.broadcast_to(pos, (3, b, s))
    return pos


def _layer_apply(lp, x, cfg: ModelConfig, i: int, positions, constrain):
    kind = cfg.pattern[i]
    rs = cfg.residual_scale
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "attn":
        mix = attn_apply(lp["attn"], h, cfg, positions)
    elif kind == "local_attn":
        mix = attn_apply(lp["attn"], h, cfg, positions, window=cfg.local_window)
    elif kind == "rglru":
        mix = rglru_apply(lp["mixer"], h, cfg)
    else:  # mamba2
        mix = ssd_apply(lp["mixer"], h, cfg)
    # constrain the block OUTPUT before the residual add: the TP psum can
    # then lower as reduce-scatter straight into the seq-sharded layout
    # instead of a full all-reduce followed by a slice
    mix = constrain(mix, ("act_batch", "act_seq", "act_embed"))
    x = x + rs * mix
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    if "ln2" in lp:
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            ffn = moe_apply(lp["moe"], h, cfg, constrain)
        else:
            ffn = mlp_apply(lp["mlp"], h, cfg.mlp_kind)
        ffn = constrain(ffn, ("act_batch", "act_seq", "act_embed"))
        x = x + rs * ffn
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    return x


def forward(params, cfg: ModelConfig, batch, *, constrain: Constrain = _noop_constrain,
            remat: bool = False) -> jax.Array:
    """Full-sequence forward -> logits (B,S,V) [or (B,S,C,V)]."""
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    positions = _positions(cfg, batch, b, s)

    def one_layer(lp, x, i):
        return _layer_apply(lp, x, cfg, i, positions, constrain)

    if remat:
        one_layer = jax.checkpoint(one_layer, static_argnums=(2,))
    for i, lp in enumerate(params["layers"]):
        x = one_layer(lp, x, i)
    return _logits_out(params, cfg, x)


def loss_fn(params, cfg: ModelConfig, batch, *, constrain: Constrain = _noop_constrain,
            remat: bool = False) -> jax.Array:
    """Next-token cross entropy. labels: (B,S) or (B,S,C); -100 ignored."""
    logits = forward(params, cfg, batch, constrain=constrain, remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].clip(0).astype(jnp.int32), axis=-1
    )[..., 0]
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Serving: prefill + decode with per-layer caches
# ---------------------------------------------------------------------------

def cache_struct(cfg: ModelConfig, batch: int, max_len: int, abstract: bool = False):
    caches = []
    for i, kind in enumerate(cfg.pattern):
        if kind == "attn":
            caches.append(attn_cache_struct(cfg, batch, max_len, None, abstract))
        elif kind == "local_attn":
            caches.append(attn_cache_struct(cfg, batch, max_len,
                                            cfg.local_window, abstract))
        elif kind == "rglru":
            caches.append(rglru_cache_struct(cfg, batch, abstract))
        else:
            caches.append(ssd_cache_struct(cfg, batch, abstract))
    return caches


def cache_axes(cfg: ModelConfig):
    """Logical axes tree matching cache_struct."""
    from repro.nn.layers import Axes
    axes = []
    for kind in cfg.pattern:
        if kind in ("attn", "local_attn"):
            a = Axes(("act_batch", "kv_heads_n", "cache_seq", "head_dim"))
            axes.append({"k": a, "v": a})
        elif kind == "rglru":
            axes.append({"h": Axes(("act_batch", "lru")),
                         "conv": Axes(("act_batch", "conv_w", "lru"))})
        else:
            axes.append({"state": Axes(("act_batch", "ssm_heads", "ssm_p",
                                        "ssm_state")),
                         "conv": Axes(("act_batch", "conv_w", "ssm_conv"))})
    return axes


def prefill(params, cfg: ModelConfig, batch, max_len: int,
            *, constrain: Constrain = _noop_constrain):
    """Run the prompt, return (last-position logits, caches)."""
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    positions = _positions(cfg, batch, b, s)
    caches = []
    for i, lp in enumerate(params["layers"]):
        kind = cfg.pattern[i]
        rs = cfg.residual_scale
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if kind in ("attn", "local_attn"):
            window = cfg.local_window if kind == "local_attn" else None
            mix, (k, v) = attn_apply(lp["attn"], h, cfg, positions,
                                     window=window, return_kv=True)
            caches.append(attn_prefill_cache(k, v, max_len, window))
        elif kind == "rglru":
            mix, cache = rglru_apply(lp["mixer"], h, cfg, return_state=True)
            caches.append(cache)
        else:
            mix, cache = ssd_prefill_cache(lp["mixer"], h, cfg)
            caches.append(cache)
        x = x + rs * mix
        if "ln2" in lp:
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            ffn = moe_apply(lp["moe"], h, cfg, constrain) if "moe" in lp else \
                mlp_apply(lp["mlp"], h, cfg.mlp_kind)
            x = x + rs * ffn
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    logits = _logits_out(params, cfg, x[:, -1:])
    return logits, caches


def decode_step(params, cfg: ModelConfig, batch, caches, *,
                constrain: Constrain = _noop_constrain):
    """One decode step. batch: {"tokens": (B,1[,C]) | "embeddings": (B,1,D),
    "pos": scalar int32}. Returns (logits, new_caches)."""
    pos = batch["pos"]
    x = _embed_in(params, cfg, batch)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    new_caches = []
    for i, lp in enumerate(params["layers"]):
        kind = cfg.pattern[i]
        rs = cfg.residual_scale
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if kind in ("attn", "local_attn"):
            window = cfg.local_window if kind == "local_attn" else None
            mix, cache = attn_decode(lp["attn"], h, cfg, caches[i], pos,
                                     window=window)
        elif kind == "rglru":
            mix, cache = rglru_decode(lp["mixer"], h, cfg, caches[i])
        else:
            mix, cache = ssd_decode(lp["mixer"], h, cfg, caches[i])
        new_caches.append(cache)
        x = x + rs * mix
        if "ln2" in lp:
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            ffn = moe_apply(lp["moe"], h, cfg, constrain) if "moe" in lp else \
                mlp_apply(lp["mlp"], h, cfg.mlp_kind)
            x = x + rs * ffn
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    logits = _logits_out(params, cfg, x)
    return logits, new_caches

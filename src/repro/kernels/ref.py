"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth used by tests/test_kernels.py
(assert_allclose vs the kernel in interpret mode across shape/dtype sweeps)
and as the CPU fallback backend in kernels/ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _activate(x, activation: str):
    if activation == "none":
        return x
    if activation == "relu":
        return jax.nn.relu(x)
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "silu":
        return jax.nn.silu(x)
    raise ValueError(f"unknown activation {activation}")


def dense_engine(x, w, b=None, *, activation: str = "none"):
    """Dense Engine oracle: act(x @ w + b).

    x: (M, K), w: (K, N), b: (N,) or None.
    """
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return _activate(out, activation).astype(x.dtype)


def shard_spmm(blocks, h):
    """Graph Engine (linear aggregation) oracle.

    blocks: (S_dst, S_src, n, n) densified per-shard adjacency,
            A[i, j, v, u] (rectangular grids welcome — dist/gnn.py
            aggregates local dst rows against the full source grid).
    h:      (S_src, n, D) node features grouped by shard.
    returns (S_dst, n, D): out[i, v] = sum_{j,u} A[i,j,v,u] * h[j,u].
    """
    return jnp.einsum(
        "ijvu,jud->ivd",
        blocks.astype(jnp.float32),
        h.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(h.dtype)


def fused_gnn(blocks, h, w, *, activation: str = "none"):
    """Fused aggregation + feature extraction oracle (inter-stage fusion).

    out = act( (A · H) · W ):  blocks (S,S,n,n), h (S,n,D), w (D,F)
    returns (S, n, F).
    """
    agg = jnp.einsum(
        "ijvu,jud->ivd",
        blocks.astype(jnp.float32),
        h.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out = jnp.einsum("ivd,df->ivf", agg, w.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return _activate(out, activation).astype(h.dtype)


def seg_gather_agg(edge_src, edge_dst, edge_valid, h_src, n_dst: int, *, op: str = "max",
                   keep_identity: bool = False):
    """Edge-list aggregation oracle for one (dst, src) shard pair.

    edge_src/edge_dst: (E,) int32 local node ids; edge_valid: (E,) bool.
    h_src: (n_src, D). Returns (n_dst, D) with identity element where a
    destination has no valid in-edges (0 for sum/mean, -inf->0 for max,
    unless keep_identity — used when combining partial maxes across shards).
    """
    d = h_src.shape[-1]
    gathered = h_src.astype(jnp.float32)[edge_src]            # (E, D)
    if op == "max":
        neg = jnp.float32(-jnp.inf)
        gathered = jnp.where(edge_valid[:, None], gathered, neg)
        out = jnp.full((n_dst, d), neg, dtype=jnp.float32)
        out = out.at[edge_dst].max(gathered, mode="drop")
        if not keep_identity:
            out = jnp.where(jnp.isfinite(out), out, 0.0)
        return out.astype(jnp.float32) if keep_identity else out.astype(h_src.dtype)
    elif op in ("sum", "mean"):
        gathered = jnp.where(edge_valid[:, None], gathered, 0.0)
        out = jnp.zeros((n_dst, d), dtype=jnp.float32)
        out = out.at[edge_dst].add(
            jnp.where(edge_valid[:, None], gathered, 0.0), mode="drop")
        if op == "mean":
            cnt = jnp.zeros((n_dst,), jnp.float32).at[edge_dst].add(
                edge_valid.astype(jnp.float32), mode="drop")
            out = out / jnp.maximum(cnt, 1.0)[:, None]
    else:
        raise ValueError(f"unknown op {op}")
    return out.astype(h_src.dtype)


# --------------------------------------------------------------------------
# GNN model-zoo layer oracles (repro.gnn.models). These operate on FLAT
# (N, D) features and a densified (N, N) adjacency — the ground truth the
# shard-grid engine path must reproduce exactly (tests/test_gnn_models.py).
# The adjacency carries the normalization baked by core.sharding.shard_graph
# (gcn / mean / sum weights); masks are derived as adj != 0.
# --------------------------------------------------------------------------

def gcn_layer(adj, h, w, *, activation: str = "none"):
    """act((Â H) W) — flat GCN layer; adj is the gcn-normalized adjacency."""
    agg = jnp.dot(adj.astype(jnp.float32), h.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return dense_engine(agg.astype(h.dtype), w, activation=activation)


def sage_mean_layer(adj_mean, h, w, *, activation: str = "none"):
    """act(W [mean_agg(h); h]) — GraphSAGE mean aggregator (adj row-mean)."""
    agg = jnp.dot(adj_mean.astype(jnp.float32), h.astype(jnp.float32),
                  preferred_element_type=jnp.float32).astype(h.dtype)
    return dense_engine(jnp.concatenate([agg, h], axis=-1), w,
                        activation=activation)


def sage_max_pool_layer(adj_mask, h, w_pool, b_pool, w, *,
                        activation: str = "none"):
    """GraphSAGE max-pool: z = relu(h W_p + b_p); z̄ = max_N z; act(W [z̄;h])."""
    z = dense_engine(h, w_pool, b_pool, activation="relu").astype(jnp.float32)
    mask = (adj_mask != 0)
    neg = jnp.float32(-jnp.inf)
    # zbar[v] = max over u in N(v); identity 0 where no neighbors
    cand = jnp.where(mask[:, :, None], z[None, :, :], neg)
    zbar = jnp.max(cand, axis=1)
    zbar = jnp.where(jnp.isfinite(zbar), zbar, 0.0).astype(h.dtype)
    return dense_engine(jnp.concatenate([zbar, h], axis=-1), w,
                        activation=activation)


def gin_layer(adj_sum, h, eps, w1, b1, w2, b2, *, activation: str = "none"):
    """GIN: MLP((1+ε) h + Σ_N h); adj_sum has NO self loops (ε handles it)."""
    agg = jnp.dot(adj_sum.astype(jnp.float32), h.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    x = ((1.0 + eps) * h.astype(jnp.float32) + agg).astype(h.dtype)
    hid = dense_engine(x, w1, b1, activation="relu")
    return dense_engine(hid, w2, b2, activation=activation)


def gat_layer(adj_mask, h, w, a_src, a_dst, *, negative_slope: float = 0.2,
              activation: str = "none", concat_heads: bool = True):
    """Multi-head GAT layer.

    h: (N, D); w: (D, H*F); a_src/a_dst: (H, F); adj_mask: (N, N) nonzero
    where edge u->v exists at [v, u] (self loops included upstream).
    α_vu = softmax_u( leakyrelu(a_dst·z_v + a_src·z_u) ), out_v = Σ α z_u.
    Heads are concatenated (hidden layers) or averaged (output layer).
    """
    n = h.shape[0]
    heads, f = a_src.shape
    z = jnp.dot(h.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32).reshape(n, heads, f)
    s_src = jnp.einsum("nhf,hf->nh", z, a_src.astype(jnp.float32))
    s_dst = jnp.einsum("nhf,hf->nh", z, a_dst.astype(jnp.float32))
    logits = s_dst[:, None, :] + s_src[None, :, :]          # (V, U, H)
    logits = jax.nn.leaky_relu(logits, negative_slope)
    mask = (adj_mask != 0)[:, :, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    m = jnp.max(logits, axis=1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(mask, jnp.exp(logits - m), 0.0)
    denom = jnp.sum(e, axis=1, keepdims=True)
    alpha = jnp.where(denom > 0, e / jnp.maximum(denom, 1e-30), 0.0)
    out = jnp.einsum("vuh,uhf->vhf", alpha, z)
    out = out.reshape(n, heads * f) if concat_heads else out.mean(axis=1)
    return _activate(out, activation).astype(h.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None):
    """Attention oracle: softmax(q k^T * scale + mask) v.

    q: (B, Hq, Sq, Dh), k/v: (B, Hkv, Skv, Dh) with Hq % Hkv == 0 (GQA).
    window: local attention window (keys within [i-window+1, i]).
    """
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = scale if scale is not None else dh ** -0.5
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * s
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, sq, dh).astype(q.dtype)

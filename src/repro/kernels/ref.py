"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth used by tests/test_kernels.py
(assert_allclose vs the kernel in interpret mode across shape/dtype sweeps)
and as the CPU fallback backend in kernels/ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _activate(x, activation: str):
    if activation == "none":
        return x
    if activation == "relu":
        return jax.nn.relu(x)
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "silu":
        return jax.nn.silu(x)
    raise ValueError(f"unknown activation {activation}")


def dense_engine(x, w, b=None, *, activation: str = "none"):
    """Dense Engine oracle: act(x @ w + b).

    x: (M, K), w: (K, N), b: (N,) or None.
    """
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return _activate(out, activation).astype(x.dtype)


def shard_spmm(blocks, h):
    """Graph Engine (linear aggregation) oracle.

    blocks: (S, S, n, n) densified per-shard adjacency, A[i, j, v, u].
    h:      (S, n, D) node features grouped by shard.
    returns (S, n, D): out[i, v] = sum_{j,u} A[i,j,v,u] * h[j,u].
    """
    return jnp.einsum(
        "ijvu,jud->ivd",
        blocks.astype(jnp.float32),
        h.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(h.dtype)


def fused_gnn(blocks, h, w, *, activation: str = "none"):
    """Fused aggregation + feature extraction oracle (inter-stage fusion).

    out = act( (A · H) · W ):  blocks (S,S,n,n), h (S,n,D), w (D,F)
    returns (S, n, F).
    """
    agg = jnp.einsum(
        "ijvu,jud->ivd",
        blocks.astype(jnp.float32),
        h.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out = jnp.einsum("ivd,df->ivf", agg, w.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return _activate(out, activation).astype(h.dtype)


def seg_gather_agg(edge_src, edge_dst, edge_valid, h_src, n_dst: int, *, op: str = "max",
                   keep_identity: bool = False):
    """Edge-list aggregation oracle for one (dst, src) shard pair.

    edge_src/edge_dst: (E,) int32 local node ids; edge_valid: (E,) bool.
    h_src: (n_src, D). Returns (n_dst, D) with identity element where a
    destination has no valid in-edges (0 for sum/mean, -inf->0 for max,
    unless keep_identity — used when combining partial maxes across shards).
    """
    d = h_src.shape[-1]
    gathered = h_src.astype(jnp.float32)[edge_src]            # (E, D)
    if op == "max":
        neg = jnp.float32(-jnp.inf)
        gathered = jnp.where(edge_valid[:, None], gathered, neg)
        out = jnp.full((n_dst, d), neg, dtype=jnp.float32)
        out = out.at[edge_dst].max(gathered, mode="drop")
        if not keep_identity:
            out = jnp.where(jnp.isfinite(out), out, 0.0)
        return out.astype(jnp.float32) if keep_identity else out.astype(h_src.dtype)
    elif op in ("sum", "mean"):
        gathered = jnp.where(edge_valid[:, None], gathered, 0.0)
        out = jnp.zeros((n_dst, d), dtype=jnp.float32)
        out = out.at[edge_dst].add(
            jnp.where(edge_valid[:, None], gathered, 0.0), mode="drop")
        if op == "mean":
            cnt = jnp.zeros((n_dst,), jnp.float32).at[edge_dst].add(
                edge_valid.astype(jnp.float32), mode="drop")
            out = out / jnp.maximum(cnt, 1.0)[:, None]
    else:
        raise ValueError(f"unknown op {op}")
    return out.astype(h_src.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    window: int | None = None):
    """Attention oracle: softmax(q k^T * scale + mask) v.

    q: (B, Hq, Sq, Dh), k/v: (B, Hkv, Skv, Dh) with Hq % Hkv == 0 (GQA).
    window: local attention window (keys within [i-window+1, i]).
    """
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = scale if scale is not None else dh ** -0.5
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * s
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, sq, dh).astype(q.dtype)

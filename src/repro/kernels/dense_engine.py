"""Dense Engine kernel (paper §III-A) — Pallas blocked matmul on the MXU.

The ASIC's 2-D systolic array with double-buffered input/weight/output
scratchpads and *partial-sum reload* maps to: a (bm × bn) f32 accumulator
held in VMEM scratch, K-blocked accumulation over the contraction axis
(the psum "reload" never leaves VMEM), fused bias + activation on the last
K step (the ASIC's 1-D activation unit), and Pallas's implicit grid
pipelining standing in for double-buffering.

Target: TPU (MXU-aligned tiles, multiples of 128). Validated on CPU via
interpret mode against kernels/ref.py::dense_engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import _activate


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, activation: str, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        out = acc_ref[...]
        if b_ref is not None:
            out = out + b_ref[...].astype(jnp.float32)
        o_ref[...] = _activate(out, activation).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "bm", "bn", "bk", "interpret"),
)
def dense_engine_matmul(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    activation: str = "none",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """act(x @ w + b) with explicit VMEM tiling.

    x: (M, K), w: (K, N), b: (N,) optional. M/K/N must be divisible by the
    block sizes (ops.py pads).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape, bm, bn, bk)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        args.append(b)
        kernel = functools.partial(_kernel, activation=activation, nk=nk)
    else:
        kernel = functools.partial(
            lambda xr, wr, orf, accr, **kw: _kernel(xr, wr, None, orf, accr, **kw),
            activation=activation,
            nk=nk,
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)

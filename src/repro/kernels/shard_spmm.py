"""Graph Engine linear-aggregation kernel with feature dimension-blocking.

This kernel IS the paper's Algorithm 1 expressed as a Pallas grid:

    grid = (D/B, S_dst, S_src)          # (blockD, dst, src) loop nest
    for blockD:                          # dimension-blocking outer loop
      for dst:                           # dst-stationary traversal
        for src:                         # moving source shards
          out[dst, :, blockD] += A[dst, src] @ h[src, :, blockD]

Only an (n × B) feature tile per shard is resident in VMEM at a time —
exactly the paper's trade: larger shards (n) for a fixed on-chip budget at
the cost of walking the shard grid D/B times. The densified (n × n)
adjacency block feeds the MXU (the TPU-native replacement for the ASIC's
edge-by-edge SIMD Apply/Reduce lanes; see DESIGN.md §2).

The (n × B) f32 accumulator in VMEM scratch plays the role of the Graph
Engine's destination scratchpad: destination features stay resident until
fully aggregated (dst-stationary), then are written back once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, h_ref, o_ref, acc_ref, *, ns: int):
    j = pl.program_id(2)  # src shard (innermost, accumulated)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], h_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(j == ns - 1)
    def _writeback():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def shard_spmm(
    blocks: jax.Array,
    h: jax.Array,
    *,
    block_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """out[i] = sum_j A[i, j] @ h[j], feature-blocked.

    blocks: (S_dst, S_src, n, n) densified adjacency; h: (S_src, n, D)
    shard-grouped node features; D must be divisible by block_b (ops.py
    pads). Returns (S_dst, n, D). The grid may be rectangular — the
    sharded executable (dist/gnn.py) hands each data-group its own
    contiguous dst rows against the full gathered source grid.
    """
    s, s_src, n, n2 = blocks.shape
    s3, n3, d = h.shape
    assert s_src == s3 and n == n2 == n3, (blocks.shape, h.shape)
    assert d % block_b == 0, (d, block_b)
    grid = (d // block_b, s, s_src)  # (blockD, dst, src) — Algorithm 1

    return pl.pallas_call(
        functools.partial(_kernel, ns=s_src),
        grid=grid,
        in_specs=[
            # adjacency block for (dst=i, src=j); dims 0,1 squeezed
            pl.BlockSpec((None, None, n, n), lambda bd, i, j: (i, j, 0, 0)),
            # source features: shard j, dimension block bd
            pl.BlockSpec((None, n, block_b), lambda bd, i, j: (j, 0, bd)),
        ],
        out_specs=pl.BlockSpec((None, n, block_b), lambda bd, i, j: (i, 0, bd)),
        out_shape=jax.ShapeDtypeStruct((s, n, d), h.dtype),
        scratch_shapes=[pltpu.VMEM((n, block_b), jnp.float32)],
        interpret=interpret,
    )(blocks, h)

"""Pluggable kernel-backend registry (the runtime's hardware abstraction).

Every compute primitive the engines need is one method on the
:class:`KernelBackend` protocol. Three backends ship in-tree:

  pallas      the Pallas kernels (interpret mode on CPU, compiled on TPU),
              shape-safe padding at the boundary, backward pass derived
              from the pure-jnp oracles via ``custom_vjp``.
  jax         pure-XLA lowering: fully vectorized ``jnp`` implementations
              (vmapped segment ops instead of per-shard Python loops) that
              XLA fuses on any device. Ad-traceable end to end.
  reference   the semantic ground truth from :mod:`repro.kernels.ref` —
              written for clarity (explicit per-shard-pair loops), used as
              the oracle everything else is pinned against.

Selection precedence, most specific wins:

  1. an explicit backend passed per call / per ``runtime.compile(...)``,
  2. a per-op override in ``REPRO_KERNEL_BACKEND_<OP>`` (op upper-cased),
  3. the global ``REPRO_KERNEL_BACKEND`` env var,
  4. the default, ``pallas``.

``ref`` is accepted everywhere as a legacy alias for ``reference``.
"""
from __future__ import annotations

import os
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.kernels import dense_engine as _de
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_gnn as _fg
from repro.kernels import ref
from repro.kernels import seg_gather as _sg
from repro.kernels import shard_spmm as _ss
from repro.utils import round_up

DEFAULT_BACKEND = "pallas"

# the ops every backend must provide (= the registry's per-op override keys)
OP_NAMES = ("dense_matmul", "graph_aggregate", "fused_aggregate_extract",
            "gather_aggregate", "attention")


@runtime_checkable
class KernelBackend(Protocol):
    """One implementation of every engine compute primitive."""

    name: str

    def dense_matmul(self, x, w, b=None, *, activation: str = "none",
                     bm: int = 128, bn: int = 128, bk: int = 128):
        """act(x @ w + b); x (M, K), w (K, N), b (N,) or None."""
        ...

    def graph_aggregate(self, blocks, h, *, block_b: int = 128):
        """Linear shard-grid aggregation: out[i] = Σ_j A[i,j] @ h[j]."""
        ...

    def fused_aggregate_extract(self, blocks, h, w, *,
                                activation: str = "none", block_b: int = 128):
        """act((A·H)·W) with h_agg never leaving on-chip memory."""
        ...

    def gather_aggregate(self, edge_src, edge_dst, edge_valid, h, *,
                         op: str = "max", block_b: int = 128):
        """Edge-list (gather/scatter) aggregation; supports max/sum."""
        ...

    def attention(self, q, k, v, *, causal: bool = True,
                  window: int | None = None, scale: float | None = None,
                  bq: int = 128, bk: int = 128):
        """Attention; q (B,Hq,Sq,Dh), k/v (B,Hkv,Skv,Dh)."""
        ...


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

def _with_ref_vjp(kernel_fn, ref_fn):
    """custom_vjp wrapper: FORWARD runs the Pallas kernel, BACKWARD
    differentiates the pure-jnp oracle (recomputing the forward pass —
    kernels in interpret mode are not ad-traceable, and shipping explicit
    VJPs per kernel is exactly what production kernel libraries do; the
    oracle-derived gradient is validated in tests/test_kernels_grad.py)."""
    @jax.custom_vjp
    def f(*args):
        return kernel_fn(*args)

    def fwd(*args):
        return kernel_fn(*args), args

    def bwd(args, g):
        _, vjp = jax.vjp(ref_fn, *args)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def _interpret() -> bool:
    # interpret unless we are actually on TPU
    return jax.default_backend() != "tpu"


def _pad(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _gather_loop(edge_src, edge_dst, edge_valid, h, *, op: str):
    """Per-shard-pair Python loop over the grid (the readable reference)."""
    s, n, _ = h.shape
    outs = []
    for i in range(s):
        acc = None
        for j in range(s):
            part = ref.seg_gather_agg(
                edge_src[i, j], edge_dst[i, j], edge_valid[i, j],
                h[j], n, op=op, keep_identity=(op == "max"))
            acc = part if acc is None else (
                jnp.maximum(acc, part) if op == "max" else acc + part)
        if op == "max":
            acc = jnp.where(jnp.isfinite(acc), acc, 0.0).astype(h.dtype)
        outs.append(acc)
    return jnp.stack(outs)


# --------------------------------------------------------------------------
# reference backend: the oracles, verbatim
# --------------------------------------------------------------------------

class ReferenceBackend:
    """Semantic ground truth (kernels/ref.py); clarity over speed."""

    name = "reference"

    def dense_matmul(self, x, w, b=None, *, activation="none",
                     bm=128, bn=128, bk=128):
        return ref.dense_engine(x, w, b, activation=activation)

    def graph_aggregate(self, blocks, h, *, block_b=128):
        return ref.shard_spmm(blocks, h)

    def fused_aggregate_extract(self, blocks, h, w, *, activation="none",
                                block_b=128):
        return ref.fused_gnn(blocks, h, w, activation=activation)

    def gather_aggregate(self, edge_src, edge_dst, edge_valid, h, *,
                         op="max", block_b=128):
        return _gather_loop(edge_src, edge_dst, edge_valid, h, op=op)

    def attention(self, q, k, v, *, causal=True, window=None, scale=None,
                  bq=128, bk=128):
        return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                   window=window)


# --------------------------------------------------------------------------
# jax backend: pure-XLA lowering, fully vectorized
# --------------------------------------------------------------------------

class JaxBackend(ReferenceBackend):
    """Pure-XLA lowering. The dense/spmm/fused/attention oracles are
    already single fused einsums, so those are shared with ``reference``;
    the one op where reference trades speed for readability — the
    per-shard-pair Python gather loop — is replaced by a vmapped segment
    aggregation that scales to large shard grids on CPU/GPU/TPU without
    Pallas."""

    name = "jax"

    def gather_aggregate(self, edge_src, edge_dst, edge_valid, h, *,
                         op="max", block_b=128):
        s, n, _ = h.shape

        def one_pair(es, ed, ev, h_src):
            return ref.seg_gather_agg(es, ed, ev, h_src, n, op=op,
                                      keep_identity=(op == "max"))

        def one_dst(es_row, ed_row, ev_row):
            # (S, E) edge rows against all S source shards at once
            parts = jax.vmap(one_pair)(es_row, ed_row, ev_row, h)
            if op == "max":
                acc = jnp.max(parts, axis=0)
                return jnp.where(jnp.isfinite(acc), acc, 0.0).astype(h.dtype)
            return jnp.sum(parts, axis=0).astype(h.dtype)

        return jax.vmap(one_dst)(edge_src, edge_dst, edge_valid)

    def attention(self, q, k, v, *, causal=True, window=None, scale=None,
                  bq=128, bk=128):
        return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                   window=window)


# --------------------------------------------------------------------------
# pallas backend: the kernels, padded at the boundary, oracle-derived VJPs
# --------------------------------------------------------------------------

class PallasBackend:
    """The Pallas kernels (interpret mode off-TPU). Inputs are padded to
    the kernels' block multiples and sliced back; backward passes come
    from the oracles via custom_vjp."""

    name = "pallas"

    def dense_matmul(self, x, w, b=None, *, activation="none",
                     bm=128, bn=128, bk=128):
        def kernel(x, w, *opt_b):
            m, k = x.shape
            n = w.shape[1]
            bm_, bn_, bk_ = (min(bm, round_up(m, 8)), min(bn, round_up(n, 8)),
                             min(bk, round_up(k, 8)))
            mp, kp, np_ = round_up(m, bm_), round_up(k, bk_), round_up(n, bn_)
            xp = _pad(_pad(x, mp, 0), kp, 1)
            wp = _pad(_pad(w, kp, 0), np_, 1)
            bp = _pad(opt_b[0], np_, 0) if opt_b else None
            out = _de.dense_engine_matmul(
                xp, wp, bp, activation=activation, bm=bm_, bn=bn_, bk=bk_,
                interpret=_interpret())
            return out[:m, :n]

        def ref_fn(x, w, *opt_b):
            return ref.dense_engine(x, w, opt_b[0] if opt_b else None,
                                    activation=activation)

        args = (x, w) if b is None else (x, w, b)
        return _with_ref_vjp(kernel, ref_fn)(*args)

    def graph_aggregate(self, blocks, h, *, block_b=128):
        def kernel(blocks, h):
            d = h.shape[-1]
            bb = min(block_b, round_up(d, 8))
            dp = round_up(d, bb)
            out = _ss.shard_spmm(blocks, _pad(h, dp, 2), block_b=bb,
                                 interpret=_interpret())
            return out[..., :d]

        return _with_ref_vjp(kernel, ref.shard_spmm)(blocks, h)

    def fused_aggregate_extract(self, blocks, h, w, *, activation="none",
                                block_b=128):
        def kernel(blocks, h, w):
            d = h.shape[-1]
            bb = min(block_b, round_up(d, 8))
            dp = round_up(d, bb)
            return _fg.fused_gnn_layer(
                blocks, _pad(h, dp, 2), _pad(w, dp, 0),
                block_b=bb, activation=activation, interpret=_interpret())

        def ref_fn(blocks, h, w):
            return ref.fused_gnn(blocks, h, w, activation=activation)

        return _with_ref_vjp(kernel, ref_fn)(blocks, h, w)

    def gather_aggregate(self, edge_src, edge_dst, edge_valid, h, *,
                         op="max", block_b=128):
        def kernel(h):
            d = h.shape[-1]
            bb = min(block_b, round_up(d, 8))
            dp = round_up(d, bb)
            out = _sg.seg_gather_aggregate(
                edge_src, edge_dst, edge_valid, _pad(h, dp, 2), op=op,
                block_b=bb, interpret=_interpret())
            return out[..., :d]

        def ref_fn(h):
            return _gather_loop(edge_src, edge_dst, edge_valid, h, op=op)

        return _with_ref_vjp(kernel, ref_fn)(h)

    def attention(self, q, k, v, *, causal=True, window=None, scale=None,
                  bq=128, bk=128):
        sq, skv = q.shape[2], k.shape[2]
        bq_, bk_ = min(bq, sq), min(bk, skv)
        if sq % bq_ or skv % bk_:
            # Padding the sequence axes would shift the causal-offset
            # alignment (qpos = skv - sq + i); rather than re-deriving masks
            # for padded layouts we require block-multiple shapes for the
            # kernel path and fall back to the oracle otherwise.
            return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                       window=window)

        def kernel(q, k, v):
            return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                       scale=scale, bq=bq_, bk=bk_,
                                       interpret=_interpret())

        def ref_fn(q, k, v):
            return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                       window=window)

        return _with_ref_vjp(kernel, ref_fn)(q, k, v)


# --------------------------------------------------------------------------
# registry + resolution
# --------------------------------------------------------------------------

_REGISTRY: dict[str, KernelBackend] = {}
_ALIASES: dict[str, str] = {"ref": "reference"}   # legacy env value


def register_backend(backend: KernelBackend, *,
                     aliases: tuple[str, ...] = ()) -> KernelBackend:
    """Register a backend under ``backend.name`` (plus optional aliases).
    Re-registering a name replaces it — deliberate, so tests/plugins can
    swap implementations."""
    _REGISTRY[backend.name] = backend
    for a in aliases:
        _ALIASES[a] = backend.name
    return backend


def get_backend(name: str) -> KernelBackend:
    key = _ALIASES.get(name, name)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"registered: {list_backends()}") from None


def list_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve(op: str | None = None,
            override: str | KernelBackend | None = None) -> KernelBackend:
    """Resolve the backend for one op (see module docstring for precedence).

    ``override`` may be a backend name or an actual backend object (e.g. a
    :func:`composite_backend`); ``op=None`` skips the per-op env override.
    """
    if override is not None:
        if isinstance(override, str):
            return get_backend(override)
        return override
    if op is not None:
        per_op = os.environ.get(f"REPRO_KERNEL_BACKEND_{op.upper()}")
        if per_op:
            return get_backend(per_op)
    return get_backend(os.environ.get("REPRO_KERNEL_BACKEND",
                                      DEFAULT_BACKEND))


class _CompositeBackend:
    """Routes each op to its own backend (per-op selection)."""

    def __init__(self, default: KernelBackend,
                 per_op: dict[str, KernelBackend]):
        self.default = default
        self.per_op = per_op
        ops = ",".join(f"{k}={v.name}" for k, v in sorted(per_op.items()))
        self.name = f"composite({default.name}; {ops})"
        for op in OP_NAMES:
            setattr(self, op, getattr(per_op.get(op, default), op))


def composite_backend(default: str | KernelBackend,
                      per_op: dict[str, str | KernelBackend]) -> KernelBackend:
    """Build a backend that answers each op from a different registry entry
    (``runtime.compile(..., op_backends={...})`` uses this)."""
    for op in per_op:
        if op not in OP_NAMES:
            raise ValueError(f"unknown op {op!r}; ops: {OP_NAMES}")
    return _CompositeBackend(
        resolve(override=default),
        {op: resolve(override=b) for op, b in per_op.items()})


register_backend(PallasBackend())
register_backend(JaxBackend())
register_backend(ReferenceBackend(), aliases=("ref",))

"""Blocked online-softmax (flash) attention kernel.

Not part of the paper's GNN contribution, but the LM fleet's dominant
compute hot-spot — and the clearest transfer of the paper's insight to
transformers: *block a reduction axis so only a small tile is resident*.
Here the "feature block" is a kv-chunk: the (bq × bk) logit tile and the
(bq × dh) accumulator live in VMEM; the Skv axis is walked blockwise with
running max/denominator, so the S×S score matrix never exists in HBM.

Supports GQA (Hq multiple of Hkv), causal masking, and local (sliding
window) masking. Validated in interpret mode against ref.flash_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_MASKED = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            sq: int, skv: int, bq: int, bk: int, nk: int):
    i, kk = pl.program_id(1), pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _MASKED)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)            # (bq, dh)
    k = k_ref[...].astype(jnp.float32)            # (bk, dh)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = (skv - sq) + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, _MASKED)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(logits, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)               # rescale old accumulator
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    v = v_ref[...].astype(jnp.float32)            # (bk, dh)
    acc_new = acc_prev * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(kk == nk - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """softmax(q kᵀ · scale + mask) v, blockwise.

    q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh); Hq % Hkv == 0.
    Sq % bq == 0 and Skv % bk == 0 (ops.py pads).
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    g = hq // hkv
    s = scale if scale is not None else dh ** -0.5
    nk = skv // bk

    qf = q.reshape(b * hq, sq, dh)
    kf = k.reshape(b * hkv, skv, dh)
    vf = v.reshape(b * hkv, skv, dh)

    def kv_index(bh, i, kk):
        return (bh // hq) * hkv + (bh % hq) // g, kk, 0

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=s, causal=causal, window=window,
            sq=sq, skv=skv, bq=bq, bk=bk, nk=nk,
        ),
        grid=(b * hq, sq // bq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda bh, i, kk: (bh, i, 0)),
            pl.BlockSpec((None, bk, dh), kv_index),
            pl.BlockSpec((None, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda bh, i, kk: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, dh)

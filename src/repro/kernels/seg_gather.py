"""Graph Engine gather/scatter kernel for non-linear aggregation.

Max-pool aggregation (GraphsagePool) is not a matmul, so the densified
shard_spmm path does not apply. This kernel keeps the ASIC's edge-by-edge
view: the Edge Fetcher walks the shard's COO edge list, the Feature Fetcher
gathers source rows, and the SIMD Reduce lane scatter-reduces into the
destination scratchpad — all on an (n × B) dimension block resident in
VMEM, with the same (blockD, dst, src) loop nest as shard_spmm.

Edge ids are int32 and live in VMEM blocks (on real TPU one would prefetch
them to SMEM with PrefetchScalarGridSpec; functionally identical).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -3.0e38  # python float: jnp constants would be captured as consts


def _kernel(src_ref, dst_ref, valid_ref, h_ref, o_ref, acc_ref, *, ns: int, op: str):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, _NEG if op == "max" else 0.0)

    src = src_ref[...]
    dst = dst_ref[...]
    valid = valid_ref[...] != 0
    h = h_ref[...].astype(jnp.float32)          # (n_src, B) resident block
    gathered = h[src]                            # (E, B) Feature Fetcher
    acc = acc_ref[...]
    if op == "max":
        gathered = jnp.where(valid[:, None], gathered, _NEG)
        acc = acc.at[dst].max(gathered, mode="drop")
    else:  # sum
        gathered = jnp.where(valid[:, None], gathered, 0.0)
        acc = acc.at[dst].add(gathered, mode="drop")
    acc_ref[...] = acc

    @pl.when(j == ns - 1)
    def _writeback():
        out = acc_ref[...]
        if op == "max":
            out = jnp.where(out <= _NEG / 2, 0.0, out)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("op", "block_b", "interpret"))
def seg_gather_aggregate(
    edge_src: jax.Array,
    edge_dst: jax.Array,
    edge_valid: jax.Array,
    h: jax.Array,
    *,
    op: str = "max",
    block_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Edge-list shard-grid aggregation, feature-blocked.

    edge_src/edge_dst: (S, S, E) int32 local ids; edge_valid: (S, S, E)
    int8/bool; h: (S, n, D). Returns (S, n, D) aggregated per destination.
    """
    s, s2, e = edge_src.shape
    s3, n, d = h.shape
    assert s == s2 == s3, (edge_src.shape, h.shape)
    assert d % block_b == 0, (d, block_b)
    assert op in ("max", "sum"), op
    valid = edge_valid.astype(jnp.int8)
    grid = (d // block_b, s, s)  # (blockD, dst, src)

    return pl.pallas_call(
        functools.partial(_kernel, ns=s, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, e), lambda bd, i, j: (i, j, 0)),
            pl.BlockSpec((None, None, e), lambda bd, i, j: (i, j, 0)),
            pl.BlockSpec((None, None, e), lambda bd, i, j: (i, j, 0)),
            pl.BlockSpec((None, n, block_b), lambda bd, i, j: (j, 0, bd)),
        ],
        out_specs=pl.BlockSpec((None, n, block_b), lambda bd, i, j: (i, 0, bd)),
        out_shape=jax.ShapeDtypeStruct((s, n, d), h.dtype),
        scratch_shapes=[pltpu.VMEM((n, block_b), jnp.float32)],
        interpret=interpret,
    )(edge_src, edge_dst, valid, h)

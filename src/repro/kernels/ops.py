"""Public, shape-safe entry points for the kernel ops.

Each function dispatches through the kernel-backend registry
(:mod:`repro.kernels.registry`): ``pallas`` (default; interpret mode on
CPU, compiled on TPU), ``jax`` (pure-XLA lowering) or ``reference`` (the
pure-jnp oracles). Selection, most specific wins:

    ops.dense_matmul(..., backend="jax")        per call
    REPRO_KERNEL_BACKEND_DENSE_MATMUL=jax       per op (env)
    REPRO_KERNEL_BACKEND=reference              global (env; "ref" is a
                                                legacy alias)

This module is a compatibility façade — new code should resolve a backend
once (``registry.resolve`` / ``runtime.compile(..., backend=...)``) and
call its methods directly.
"""
from __future__ import annotations

from repro.kernels import registry


def dense_matmul(x, w, b=None, *, activation: str = "none",
                 bm: int = 128, bn: int = 128, bk: int = 128, backend=None):
    """act(x @ w + b); x (M, K), w (K, N)."""
    return registry.resolve("dense_matmul", backend).dense_matmul(
        x, w, b, activation=activation, bm=bm, bn=bn, bk=bk)


def graph_aggregate(blocks, h, *, block_b: int = 128, backend=None):
    """Linear shard-grid aggregation: out[i] = Σ_j A[i,j] @ h[j]."""
    return registry.resolve("graph_aggregate", backend).graph_aggregate(
        blocks, h, block_b=block_b)


def fused_aggregate_extract(blocks, h, w, *, activation: str = "none",
                            block_b: int = 128, backend=None):
    """act((A·H)·W) with h_agg kept in VMEM (inter-stage fusion)."""
    return registry.resolve(
        "fused_aggregate_extract", backend).fused_aggregate_extract(
        blocks, h, w, activation=activation, block_b=block_b)


def gather_aggregate(edge_src, edge_dst, edge_valid, h, *, op: str = "max",
                     block_b: int = 128, backend=None):
    """Edge-list (gather/scatter) aggregation; supports max/sum."""
    return registry.resolve("gather_aggregate", backend).gather_aggregate(
        edge_src, edge_dst, edge_valid, h, op=op, block_b=block_b)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, bq: int = 128, bk: int = 128,
              backend=None):
    """Flash attention; q (B,Hq,Sq,Dh), k/v (B,Hkv,Skv,Dh)."""
    return registry.resolve("attention", backend).attention(
        q, k, v, causal=causal, window=window, scale=scale, bq=bq, bk=bk)

"""Public, shape-safe entry points for the Pallas kernels.

Each op pads its inputs to the kernel's block multiples, dispatches to the
Pallas kernel (interpret mode on CPU; compiled on TPU) or to the pure-jnp
oracle in ref.py, and slices the result back. Backend selection:

    REPRO_KERNEL_BACKEND=pallas   (default) Pallas kernels, interpret on CPU
    REPRO_KERNEL_BACKEND=ref      pure-jnp oracles (fast on CPU; used by the
                                  distributed/pjit paths where a per-device
                                  interpret loop would be pointless)
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import dense_engine as _de
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_gnn as _fg
from repro.kernels import ref
from repro.kernels import seg_gather as _sg
from repro.kernels import shard_spmm as _ss
from repro.utils import round_up


def _backend() -> str:
    return os.environ.get("REPRO_KERNEL_BACKEND", "pallas")


def _with_ref_vjp(kernel_fn, ref_fn):
    """custom_vjp wrapper: FORWARD runs the Pallas kernel, BACKWARD
    differentiates the pure-jnp oracle (recomputing the forward pass —
    kernels in interpret mode are not ad-traceable, and shipping explicit
    VJPs per kernel is exactly what production kernel libraries do; the
    oracle-derived gradient is validated in tests/test_kernels_grad.py)."""
    @jax.custom_vjp
    def f(*args):
        return kernel_fn(*args)

    def fwd(*args):
        return kernel_fn(*args), args

    def bwd(args, g):
        _, vjp = jax.vjp(ref_fn, *args)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def _interpret() -> bool:
    # interpret unless we are actually on TPU
    return jax.default_backend() != "tpu"


def _pad(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def dense_matmul(x, w, b=None, *, activation: str = "none",
                 bm: int = 128, bn: int = 128, bk: int = 128):
    """act(x @ w + b); x (M, K), w (K, N)."""
    if _backend() == "ref":
        return ref.dense_engine(x, w, b, activation=activation)

    def kernel(x, w, *opt_b):
        m, k = x.shape
        n = w.shape[1]
        bm_, bn_, bk_ = (min(bm, round_up(m, 8)), min(bn, round_up(n, 8)),
                         min(bk, round_up(k, 8)))
        mp, kp, np_ = round_up(m, bm_), round_up(k, bk_), round_up(n, bn_)
        xp = _pad(_pad(x, mp, 0), kp, 1)
        wp = _pad(_pad(w, kp, 0), np_, 1)
        bp = _pad(opt_b[0], np_, 0) if opt_b else None
        out = _de.dense_engine_matmul(
            xp, wp, bp, activation=activation, bm=bm_, bn=bn_, bk=bk_,
            interpret=_interpret())
        return out[:m, :n]

    def ref_fn(x, w, *opt_b):
        return ref.dense_engine(x, w, opt_b[0] if opt_b else None,
                                activation=activation)

    args = (x, w) if b is None else (x, w, b)
    return _with_ref_vjp(kernel, ref_fn)(*args)


def graph_aggregate(blocks, h, *, block_b: int = 128):
    """Linear shard-grid aggregation: out[i] = Σ_j A[i,j] @ h[j]."""
    if _backend() == "ref":
        return ref.shard_spmm(blocks, h)

    def kernel(blocks, h):
        d = h.shape[-1]
        bb = min(block_b, round_up(d, 8))
        dp = round_up(d, bb)
        out = _ss.shard_spmm(blocks, _pad(h, dp, 2), block_b=bb,
                             interpret=_interpret())
        return out[..., :d]

    return _with_ref_vjp(kernel, ref.shard_spmm)(blocks, h)


def fused_aggregate_extract(blocks, h, w, *, activation: str = "none",
                            block_b: int = 128):
    """act((A·H)·W) with h_agg kept in VMEM (inter-stage fusion)."""
    if _backend() == "ref":
        return ref.fused_gnn(blocks, h, w, activation=activation)

    def kernel(blocks, h, w):
        d = h.shape[-1]
        bb = min(block_b, round_up(d, 8))
        dp = round_up(d, bb)
        return _fg.fused_gnn_layer(
            blocks, _pad(h, dp, 2), _pad(w, dp, 0),
            block_b=bb, activation=activation, interpret=_interpret())

    def ref_fn(blocks, h, w):
        return ref.fused_gnn(blocks, h, w, activation=activation)

    return _with_ref_vjp(kernel, ref_fn)(blocks, h, w)


def gather_aggregate(edge_src, edge_dst, edge_valid, h, *, op: str = "max",
                     block_b: int = 128):
    """Edge-list (gather/scatter) aggregation; supports max/sum."""
    if _backend() == "ref":
        s, n, d = h.shape
        outs = []
        for i in range(s):
            acc = None
            for j in range(s):
                part = ref.seg_gather_agg(
                    edge_src[i, j], edge_dst[i, j], edge_valid[i, j],
                    h[j], n, op=op, keep_identity=(op == "max"))
                acc = part if acc is None else (
                    jnp.maximum(acc, part) if op == "max" else acc + part)
            if op == "max":
                acc = jnp.where(jnp.isfinite(acc), acc, 0.0).astype(h.dtype)
            outs.append(acc)
        return jnp.stack(outs)
    def kernel(h):
        d = h.shape[-1]
        bb = min(block_b, round_up(d, 8))
        dp = round_up(d, bb)
        out = _sg.seg_gather_aggregate(
            edge_src, edge_dst, edge_valid, _pad(h, dp, 2), op=op,
            block_b=bb, interpret=_interpret())
        return out[..., :d]

    def ref_fn(h):
        s, n, d = h.shape
        outs = []
        for i in range(s):
            acc = None
            for j in range(s):
                part = ref.seg_gather_agg(
                    edge_src[i, j], edge_dst[i, j], edge_valid[i, j],
                    h[j], n, op=op, keep_identity=(op == "max"))
                acc = part if acc is None else (
                    jnp.maximum(acc, part) if op == "max" else acc + part)
            if op == "max":
                acc = jnp.where(jnp.isfinite(acc), acc, 0.0).astype(h.dtype)
            outs.append(acc)
        return jnp.stack(outs)

    return _with_ref_vjp(kernel, ref_fn)(h)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, bq: int = 128, bk: int = 128):
    """Flash attention; q (B,Hq,Sq,Dh), k/v (B,Hkv,Skv,Dh)."""
    sq, skv = q.shape[2], k.shape[2]
    bq_, bk_ = min(bq, sq), min(bk, skv)
    if _backend() == "ref" or sq % bq_ or skv % bk_:
        # Padding the sequence axes would shift the causal-offset alignment
        # (qpos = skv - sq + i); rather than re-deriving masks for padded
        # layouts we require block-multiple shapes for the kernel path and
        # fall back to the oracle otherwise.
        return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                   window=window)

    def kernel(q, k, v):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale, bq=bq_, bk=bk_,
                                   interpret=_interpret())

    def ref_fn(q, k, v):
        return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                   window=window)

    return _with_ref_vjp(kernel, ref_fn)(q, k, v)

"""Fused Graph-Engine → Dense-Engine kernel (inter-stage pipelining).

The paper's GNNerator Controller lets the Dense Engine start as soon as the
Graph Engine has aggregated one *dimension block* of a destination shard
(§VI-A: "the Graph Engine only has to aggregate a small fraction of the
dimensions before the Dense Engine can begin"). On TPU there are no two
engines to synchronize — the equivalent is *fusion*: the aggregated block
h_agg is consumed by the feature-extraction matmul directly out of VMEM,
never round-tripping HBM, and the Dense Engine's partial sums over
dimension blocks accumulate in a second VMEM scratch.

    grid = (S_dst, D/B, S_src)
    for dst:
      for blockD:                      # dimension-blocking
        h_agg = 0
        for src:  h_agg += A[dst,src] @ h[src,:,blockD]      # Graph Engine
        out[dst] += h_agg @ W[blockD, :]                     # Dense Engine
      out[dst] = act(out[dst])
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import _activate


def _kernel(a_ref, h_ref, w_ref, o_ref, agg_ref, acc_ref, *, nd: int, ns: int,
            activation: str):
    d = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init_agg():
        agg_ref[...] = jnp.zeros_like(agg_ref)

    # Graph Engine step: aggregate source shard j into the resident block.
    agg_ref[...] += jnp.dot(
        a_ref[...], h_ref[...], preferred_element_type=jnp.float32
    )

    last_j = j == ns - 1

    @pl.when(jnp.logical_and(last_j, d == 0))
    def _init_out():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(last_j)
    def _dense_step():
        # Dense Engine step: consume the aggregated block from VMEM.
        acc_ref[...] += jnp.dot(
            agg_ref[...].astype(w_ref.dtype),
            w_ref[...],
            preferred_element_type=jnp.float32,
        )

    @pl.when(jnp.logical_and(last_j, d == nd - 1))
    def _writeback():
        o_ref[...] = _activate(acc_ref[...], activation).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "activation", "interpret"))
def fused_gnn_layer(
    blocks: jax.Array,
    h: jax.Array,
    w: jax.Array,
    *,
    block_b: int = 128,
    activation: str = "none",
    interpret: bool = True,
) -> jax.Array:
    """act((A · H) · W) without materializing A·H in HBM.

    blocks: (S, S, n, n); h: (S, n, D); w: (D, F). Returns (S, n, F).
    """
    s, s2, n, n2 = blocks.shape
    s3, n3, d = h.shape
    d2, f = w.shape
    assert s == s2 == s3 and n == n2 == n3 and d == d2, (blocks.shape, h.shape, w.shape)
    assert d % block_b == 0, (d, block_b)
    nd = d // block_b
    grid = (s, nd, s)  # (dst, blockD, src)

    return pl.pallas_call(
        functools.partial(_kernel, nd=nd, ns=s, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, n, n), lambda i, bd, j: (i, j, 0, 0)),
            pl.BlockSpec((None, n, block_b), lambda i, bd, j: (j, 0, bd)),
            pl.BlockSpec((block_b, f), lambda i, bd, j: (bd, 0)),
        ],
        out_specs=pl.BlockSpec((None, n, f), lambda i, bd, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, n, f), h.dtype),
        scratch_shapes=[
            pltpu.VMEM((n, block_b), jnp.float32),  # h_agg (Graph Engine out)
            pltpu.VMEM((n, f), jnp.float32),        # Dense Engine accumulator
        ],
        interpret=interpret,
    )(blocks, h, w)

"""repro.tune — empirical kernel autotuner (measured plans over Table I).

The analytic Table-I planner estimates which (shard S, feature block B,
traversal order, fused-vs-two-stage) dataflow is fastest per layer; this
package *measures* it. :func:`autotune_plan` enumerates the analytic
top-k whole-model candidates (:mod:`repro.tune.search`), times each on
the real kernel backend with warm-up + median-of-k and per-candidate
timeout/OOM guards (:mod:`repro.tune.measure`), and memoizes the winner
through the ``REPRO_PLAN_CACHE`` disk cache under an environment-scoped
key (:mod:`repro.tune.store`).

The runtime entry point is::

    exe = runtime.compile(spec, graph, backend="pallas",
                          plan="autotune", tune_budget=8)
    print(exe.summary())   # reports which source/config won and by how much

The analytic plan is always candidate #0, so the measured winner is
``>=`` the analytic choice by construction; if every measurement fails
(bad backend, OOM on every config) the analytic plan is returned as an
explicit ``analytic_fallback`` — tuning degrades, never crashes.
"""
from __future__ import annotations

import numpy as np

from repro.core.perf_model import GNNERATOR, Platform
from repro.gnn.executor import _BLOCK_CANDIDATES, plan_model
from repro.gnn.models import ZooSpec
from repro.kernels.registry import KernelBackend
from repro.tune.measure import Measurement, measure_plan
from repro.tune.search import candidate_plans, layer_config, plan_digest
from repro.tune.store import (TUNER_VERSION, TuneRecord, clear_tune_cache,
                              count_measurements, load_record, save_record,
                              tune_cache_stats, tune_key, tune_scope)

__all__ = [
    "autotune_plan", "candidate_plans", "measure_plan",
    "Measurement", "TuneRecord", "TUNER_VERSION",
    "tune_cache_stats", "clear_tune_cache", "tune_key", "tune_scope",
    "layer_config", "plan_digest",
]


def autotune_plan(spec: ZooSpec, edges: np.ndarray, num_nodes: int, *,
                  backend: KernelBackend, features=None, params: dict | None = None,
                  platform: Platform = GNNERATOR, max_n: int = 1024,
                  block_candidates: tuple[int, ...] = _BLOCK_CANDIDATES,
                  budget: int = 16, top_k: int = 4, seed: int = 0,
                  warmup: int = 1, reps: int = 3,
                  timeout_s: float | None = 30.0,
                  cache_dir=None, store=None, graph_key=None) -> TuneRecord:
    """Pick the measured-fastest ModelPlan for (spec, graph, backend).

    Args:
      spec / edges / num_nodes: the model and graph to tune for.
      backend: the *resolved* kernel backend candidates run on (its name
        is part of the winner-store key).
      features: (N, F) node features; synthesized (seeded, f32) when the
        graph is featureless — timing needs realistic shapes, not values.
      params: parameter pytree to run with; initialized from ``seed``
        when None.
      budget: max candidate plans measured, analytic plan included.
        ``budget <= 0`` skips measurement entirely and returns the
        analytic plan (``plan_source="analytic_fallback"``).
      top_k: per-layer analytic rank depth the search explores.
      seed: keys the run (and any synthesized features/params) — part of
        the memo key, so (arch, graph, budget, seed) is deterministic.
      warmup / reps / timeout_s: measurement protocol per candidate
        (see :func:`repro.tune.measure.measure_plan`).
      cache_dir: winner-store directory (default: ``REPRO_PLAN_CACHE``).
      store: GraphStore the candidates' sharded builds go through
        (default: the module-wide runtime store).
      graph_key: cache key naming the graph contents for ``store``.

    Returns the memoized :class:`~repro.tune.store.TuneRecord`; repeat
    calls with the same key re-measure nothing.
    """
    from repro.runtime.cache import default_store

    analytic = plan_model(spec, num_nodes, int(edges.shape[0]),
                          platform=platform, max_n=max_n,
                          block_candidates=block_candidates,
                          cache_dir=cache_dir)
    if budget <= 0:
        return TuneRecord(plan=analytic, plan_source="analytic_fallback",
                          winner_ms=None, analytic_ms=None, speedup=None,
                          candidates=(), scope=tune_scope(backend.name))

    key = tune_key(spec, num_nodes, int(edges.shape[0]), platform=platform,
                   max_n=max_n, block_candidates=block_candidates,
                   backend_name=backend.name, budget=budget, seed=seed,
                   reps=reps, warmup=warmup)
    rec = load_record(key, cache_dir)
    if rec is not None:
        return rec

    import jax

    if features is None:
        rng = np.random.default_rng(seed)
        features = rng.standard_normal(
            (num_nodes, spec.in_dim), dtype=np.float32)
    if params is None:
        from repro.gnn.models import init_zoo
        params = init_zoo(jax.random.key(seed), spec)
    if store is None:
        store = default_store()
    if graph_key is None:
        from repro.runtime.api import graph_fingerprint
        graph_key = graph_fingerprint(edges, num_nodes, features)

    pruned: list[dict] = []
    cands = candidate_plans(spec, num_nodes, int(edges.shape[0]),
                            analytic=analytic, platform=platform,
                            max_n=max_n, block_candidates=block_candidates,
                            top_k=top_k, budget=budget,
                            backend_name=backend.name, pruned_out=pruned)
    measured: list[tuple[Measurement, object]] = []
    for plan in cands:
        m = measure_plan(spec, plan, backend=backend, edges=edges,
                         num_nodes=num_nodes, features=features,
                         params=params, store=store, graph_key=graph_key,
                         warmup=warmup, reps=reps, timeout_s=timeout_s)
        measured.append((m, plan))
    count_measurements(len(measured))

    ok = [(m, p) for m, p in measured if m.status == "ok"]
    analytic_digest = plan_digest(analytic)
    analytic_ms = next((m.median_ms for m, _ in ok
                        if m.digest == analytic_digest), None)
    if ok:
        win_m, win_p = min(ok, key=lambda mp: mp[0].median_ms)
        speedup = (round(analytic_ms / win_m.median_ms, 4)
                   if analytic_ms else None)
        rec = TuneRecord(plan=win_p, plan_source="autotune",
                         winner_ms=round(win_m.median_ms, 4),
                         analytic_ms=(round(analytic_ms, 4)
                                      if analytic_ms else None),
                         speedup=speedup,
                         candidates=tuple(m for m, _ in measured),
                         scope=tune_scope(backend.name),
                         pruned=tuple(pruned))
    else:
        # every candidate failed (including the analytic plan): serve the
        # analytic plan anyway — it's the only choice that needs no
        # measurement to justify — and record why
        rec = TuneRecord(plan=analytic, plan_source="analytic_fallback",
                         winner_ms=None, analytic_ms=None, speedup=None,
                         candidates=tuple(m for m, _ in measured),
                         scope=tune_scope(backend.name),
                         pruned=tuple(pruned))
    save_record(key, rec, cache_dir)
    return rec

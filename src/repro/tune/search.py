"""Candidate enumeration for the empirical autotuner.

The analytic Table-I planner (:mod:`repro.gnn.executor`) ranks every
(B, n, S, order, fused) config per layer by *estimated* layer time. The
search space here is built from that ranking — per layer, the analytic
top-k — combined into whole-model :class:`~repro.gnn.executor.ModelPlan`
candidates two ways:

  * **uniform sweeps** — every layer at analytic rank r (r = 0 is the
    analytic plan itself, always candidate #0 so the measured winner can
    never lose to it), the cheap way to explore "the model wants bigger /
    smaller blocks than the paper table thinks";
  * **coordinate sweeps** — one layer moved to rank r while the others
    stay at rank 0, which is what catches a single mis-modeled layer
    (VersaGNN's observation: sparse and dense regimes want different
    tiles, and real graphs mix them across layers).

Candidates are deduplicated by their executed configuration — two plans
that differ only in analytic estimates run the same kernels, so only one
is measured — then statically pruned
(:func:`repro.analyze.plan_lint.prune_candidates`: legality violations
and execution-identical duplicates — the runtime consumes only shard_n +
per-layer (B, fused), so order/n/S variants run the same program) and
truncated to the measurement budget in rank order. Pruned candidates are
reported through ``pruned_out``, never silently dropped.
"""
from __future__ import annotations

import hashlib
import json

from repro.core.perf_model import GNNERATOR, Platform
from repro.gnn.executor import (_BLOCK_CANDIDATES, LayerPlan, ModelPlan,
                                enumerate_layer_plans)
from repro.gnn.models import ZooSpec

_ORDERS = ("src_stationary", "dst_stationary")


def plan_digest(plan: ModelPlan) -> str:
    """Hash of the *executed* configuration only (B, n, S, order, fused
    per layer) — analytic estimates don't change what runs."""
    payload = json.dumps(
        [[p.layer, p.B, p.n, p.S, str(p.order), p.fused]
         for p in plan.layers], sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def layer_config(p: LayerPlan) -> dict:
    """The measured knobs of one layer plan, JSON-friendly."""
    return {"layer": p.layer, "B": p.B, "n": p.n, "S": p.S,
            "order": str(p.order), "fused": p.fused}


def _assemble(analytic: ModelPlan, layers: list[LayerPlan]) -> ModelPlan:
    return ModelPlan(arch=analytic.arch, num_nodes=analytic.num_nodes,
                     num_edges=analytic.num_edges,
                     onchip_bytes=analytic.onchip_bytes,
                     platform=analytic.platform, layers=tuple(layers))


def candidate_plans(spec: ZooSpec, num_nodes: int, num_edges: int, *,
                    analytic: ModelPlan,
                    platform: Platform = GNNERATOR, max_n: int = 1024,
                    block_candidates: tuple[int, ...] = _BLOCK_CANDIDATES,
                    top_k: int = 4, budget: int = 16,
                    backend_name: str | None = None,
                    pruned_out: list | None = None) -> list[ModelPlan]:
    """At most ``budget`` whole-model candidates, analytic plan first.

    ``top_k`` bounds the per-layer rank depth explored; the traversal
    order axis is widened to both orders (the analytic planner only ever
    proposes the Table-I best order for a grid width).

    Candidates are statically pruned before the budget truncation —
    legality checks run against ``backend_name``'s memory budget, and
    execution-identical duplicates are dropped — so every budget slot
    goes to a distinct, runnable config. The analytic candidate #0 is
    never pruned. ``pruned_out``, when given, receives one record per
    pruned candidate (``index``/``reason``/``rules``/``detail``)."""
    if budget <= 0:
        return []
    per_layer = [
        enumerate_layer_plans(spec, i, num_nodes, num_edges,
                              platform=platform, max_n=max_n,
                              block_candidates=block_candidates,
                              orders=_ORDERS)[:max(top_k, 1)]
        for i in range(len(analytic.layers))]

    out: list[ModelPlan] = []
    seen: set[str] = set()

    def push(layers: list[LayerPlan]) -> None:
        plan = _assemble(analytic, layers)
        digest = plan_digest(plan)
        if digest not in seen:
            seen.add(digest)
            out.append(plan)

    push(list(analytic.layers))          # candidate #0: the analytic plan
    depth = max(len(c) for c in per_layer)
    for rank in range(depth):            # uniform sweeps
        push([c[min(rank, len(c) - 1)] for c in per_layer])
    if len(per_layer) > 1:
        for rank in range(1, depth):     # coordinate sweeps
            for li, cands in enumerate(per_layer):
                if rank >= len(cands):
                    continue
                layers = list(analytic.layers)
                layers[li] = cands[rank]
                push(layers)

    from repro.analyze.plan_lint import prune_candidates
    kept, pruned = prune_candidates(out, backend_name=backend_name)
    if pruned_out is not None:
        pruned_out.extend(pruned)
    return kept[:budget]

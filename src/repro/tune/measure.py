"""Measurement harness: time candidate plans on the real kernel backend.

One candidate = one :class:`~repro.runtime.executable.Executable` built
with an explicitly chosen :class:`~repro.gnn.executor.ModelPlan` (instead
of the analytic planner's pick) and timed on the full-graph forward — the
serving/training unit of work. The protocol per candidate:

  * **warm-up** — one untimed-for-score run that pays jit trace +
    backend compile; its wall time doubles as the timeout probe,
  * **median-of-k** — ``reps`` timed runs (``jax.block_until_ready``
    bracketed), scored by the median so one scheduler hiccup can't crown
    the wrong winner,
  * **guards** — a candidate that raises (XLA OOM, kernel shape error,
    anything) or whose warm-up blows the per-candidate timeout is
    recorded with its failure and *skipped*; the search never crashes.

Graph tensors are pulled through the caller's
:class:`~repro.runtime.cache.GraphStore`, so candidates that agree on
``shard_n`` share one sharded build (and the winner's build is already
resident when ``runtime.compile`` finishes up).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.gnn.executor import ModelPlan
from repro.gnn.models import ZooSpec
from repro.kernels.registry import KernelBackend
from repro.runtime.cache import GraphStore
from repro.tune.search import layer_config, plan_digest


@dataclasses.dataclass
class Measurement:
    """One candidate's timing record (also what the winner store persists)."""

    digest: str                      # executed-config hash (search.plan_digest)
    config: list[dict]               # per-layer {B, n, S, order, fused}
    status: str                      # "ok" | "error" | "timeout"
    median_ms: float | None = None   # median of the timed reps
    reps_ms: tuple[float, ...] = ()
    warmup_ms: float | None = None   # jit trace + backend compile + run
    error: str | None = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "Measurement":
        d = dict(d)
        d["reps_ms"] = tuple(d.get("reps_ms", ()))
        return cls(**d)


def measure_plan(spec: ZooSpec, plan: ModelPlan, *, backend: KernelBackend,
                 edges: np.ndarray, num_nodes: int, features,
                 params: dict, store: GraphStore, graph_key,
                 warmup: int = 1, reps: int = 3,
                 timeout_s: float | None = 30.0) -> Measurement:
    """Time one candidate plan; never raises (see module docstring)."""
    import jax

    from repro.runtime.executable import Executable

    digest = plan_digest(plan)
    config = [layer_config(p) for p in plan.layers]
    try:
        entry = store.get(graph_key, edges, num_nodes, plan.shard_n,
                          spec.arch, features=features)
        exe = Executable(spec=spec, plan=plan, backend=backend, gt=entry.gt,
                         h_grouped=entry.h_grouped, params=params,
                         graph_key=graph_key)
        t0 = time.perf_counter()
        for _ in range(max(warmup, 1)):
            jax.block_until_ready(exe.forward())
        warmup_ms = (time.perf_counter() - t0) * 1e3
        # the warm-up run doubles as the timeout probe: a candidate whose
        # compiled forward already blows the per-candidate budget is not
        # worth reps (jax computations can't be interrupted mid-flight, so
        # probing is the only timeout that doesn't leak a wedged search)
        if timeout_s is not None and warmup_ms > timeout_s * 1e3:
            return Measurement(digest=digest, config=config,
                               status="timeout", warmup_ms=warmup_ms,
                               error=f"warm-up {warmup_ms:.0f} ms exceeded "
                                     f"the {timeout_s:g} s candidate budget")
        reps_ms = []
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(exe.forward())
            reps_ms.append((time.perf_counter() - t0) * 1e3)
        return Measurement(digest=digest, config=config, status="ok",
                           median_ms=float(np.median(reps_ms)),
                           reps_ms=tuple(round(m, 4) for m in reps_ms),
                           warmup_ms=round(warmup_ms, 4))
    except Exception as err:   # noqa: BLE001 — OOM/XLA/shape errors all land
        # here; a failing candidate is a *data point*, not a crash
        return Measurement(digest=digest, config=config, status="error",
                           error=f"{type(err).__name__}: {err}")

"""Persistent winner store for autotuned plans (REPRO_PLAN_CACHE-backed).

A tuning run is expensive (budget × (warmup + reps) real forwards), so
winners are memoized twice, exactly like the analytic planner's memo:
in-process via a dict, across processes as JSON files in the same
``REPRO_PLAN_CACHE`` directory the analytic plan cache uses.

**Key scoping.** A measured winner is only meaningful in the environment
it was measured in. The key is :func:`repro.gnn.executor.plan_key` over
the same (spec, graph size, platform, knobs) payload *plus* a scope dict
carrying (plan source, kernel backend name, jax platform, jax version,
tuner version) and the search knobs (budget, seed, reps, warmup) — so a
pallas winner is never served to a reference-backend compile, and bumping
``TUNER_VERSION`` invalidates every stored winner at once.

**Corruption/staleness.** A record that fails to parse, fails schema
validation, or carries a different ``TUNER_VERSION`` is treated as a
cache miss (counted in ``tune_cache_stats()["corrupt"]``), never an
error: the caller falls back to re-tuning or the analytic plan.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib

from repro.core.perf_model import Platform
from repro.gnn.executor import ModelPlan, plan_key
from repro.gnn.models import ZooSpec
from repro.tune.measure import Measurement

# v2: static plan pruning (repro.analyze.plan_lint) changed the measured
# candidate set, so v1 winners are not comparable — bumping invalidates
# every stored record at once (stale versions load as cache misses)
TUNER_VERSION = 2

_TUNE_CACHE: dict[str, "TuneRecord"] = {}
_TUNE_STATS = {"hits": 0, "misses": 0, "disk_hits": 0, "corrupt": 0,
               "measurements": 0}


def tune_cache_stats() -> dict:
    return dict(_TUNE_STATS)


def clear_tune_cache() -> None:
    _TUNE_CACHE.clear()
    for k in _TUNE_STATS:
        _TUNE_STATS[k] = 0


def count_measurements(n: int) -> None:
    _TUNE_STATS["measurements"] += n


def tune_scope(backend_name: str) -> dict:
    """The environment half of the winner key (see module docstring)."""
    import jax
    return {
        "plan_source": "autotune",
        "backend": backend_name,
        "jax_platform": jax.default_backend(),
        "jax_version": jax.__version__,
        "tuner_version": TUNER_VERSION,
    }


def tune_key(spec: ZooSpec, num_nodes: int, num_edges: int, *,
             platform: Platform, max_n: int,
             block_candidates: tuple[int, ...], backend_name: str,
             budget: int, seed: int, reps: int, warmup: int) -> str:
    scope = {**tune_scope(backend_name),
             "budget": budget, "seed": seed, "reps": reps, "warmup": warmup}
    return plan_key(spec, num_nodes, num_edges, platform=platform,
                    max_n=max_n, block_candidates=block_candidates,
                    scope=scope)


@dataclasses.dataclass
class TuneRecord:
    """The memoized outcome of one tuning run."""

    plan: ModelPlan                  # the winner (analytic on fallback)
    plan_source: str                 # "autotune" | "analytic_fallback"
    winner_ms: float | None          # winner's median forward
    analytic_ms: float | None        # analytic plan's median forward
    speedup: float | None            # analytic_ms / winner_ms
    candidates: tuple[Measurement, ...]
    scope: dict                      # environment the timings are valid in
    # candidates rejected by static analysis before any measurement
    # (repro.analyze.plan_lint.prune_candidates records), never silently
    # dropped from the report
    pruned: tuple[dict, ...] = ()

    @property
    def n_measured(self) -> int:
        return len(self.candidates)

    def report(self) -> dict:
        """What Executable.summary() and the benchmarks surface."""
        from repro.tune.search import layer_config
        errors = sum(1 for m in self.candidates if m.status != "ok")
        by_reason: dict[str, int] = {}
        for p in self.pruned:
            r = p.get("reason", "unknown")
            by_reason[r] = by_reason.get(r, 0) + 1
        return {"plan_source": self.plan_source,
                "winner_ms": self.winner_ms,
                "analytic_ms": self.analytic_ms,
                "speedup": self.speedup,
                "candidates_measured": self.n_measured,
                "candidates_failed": errors,
                "candidates_pruned": len(self.pruned),
                "pruned_reasons": by_reason,
                "winner_config": [layer_config(p) for p in self.plan.layers]}

    def to_json(self) -> dict:
        return {"tuner_version": self.scope.get("tuner_version"),
                "plan": self.plan.to_json(),
                "plan_source": self.plan_source,
                "winner_ms": self.winner_ms,
                "analytic_ms": self.analytic_ms,
                "speedup": self.speedup,
                "candidates": [m.to_json() for m in self.candidates],
                "pruned": [dict(p) for p in self.pruned],
                "scope": self.scope}

    @classmethod
    def from_json(cls, d: dict) -> "TuneRecord":
        if d.get("tuner_version") != TUNER_VERSION:
            raise ValueError(f"stale tuner_version {d.get('tuner_version')}")
        if d.get("plan_source") not in ("autotune", "analytic_fallback"):
            raise ValueError(f"bad plan_source {d.get('plan_source')!r}")
        return cls(plan=ModelPlan.from_json(d["plan"]),
                   plan_source=d["plan_source"],
                   winner_ms=d.get("winner_ms"),
                   analytic_ms=d.get("analytic_ms"),
                   speedup=d.get("speedup"),
                   candidates=tuple(Measurement.from_json(m)
                                    for m in d.get("candidates", ())),
                   pruned=tuple(dict(p) for p in d.get("pruned", ())),
                   scope=dict(d.get("scope", {})))


def _disk_path(key: str, cache_dir) -> pathlib.Path | None:
    if cache_dir is None:
        cache_dir = os.environ.get("REPRO_PLAN_CACHE") or None
    if cache_dir is None:
        return None
    return pathlib.Path(cache_dir) / f"tune-{key}.json"


def load_record(key: str, cache_dir=None) -> TuneRecord | None:
    """Memo lookup: in-process dict, then disk. Corrupt/stale disk entries
    count as misses (and are left in place for post-mortems)."""
    rec = _TUNE_CACHE.get(key)
    if rec is not None:
        _TUNE_STATS["hits"] += 1
        return rec
    disk = _disk_path(key, cache_dir)
    if disk is not None and disk.exists():
        try:
            rec = TuneRecord.from_json(json.loads(disk.read_text()))
        except Exception:   # noqa: BLE001 — any parse/schema/version
            # failure degrades to a miss; tuning (or the analytic plan)
            # takes over instead of an unserveable model
            _TUNE_STATS["corrupt"] += 1
        else:
            _TUNE_STATS["disk_hits"] += 1
            _TUNE_CACHE[key] = rec
            return rec
    _TUNE_STATS["misses"] += 1
    return None


def save_record(key: str, rec: TuneRecord, cache_dir=None) -> None:
    _TUNE_CACHE[key] = rec
    disk = _disk_path(key, cache_dir)
    if disk is not None:
        disk.parent.mkdir(parents=True, exist_ok=True)
        disk.write_text(json.dumps(rec.to_json()) + "\n")

"""Small shared utilities."""
from __future__ import annotations

import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pad_to(x, size: int, axis: int = 0, value=0.0):
    """Pad numpy/jax array along `axis` up to `size`."""
    import jax.numpy as jnp

    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    if isinstance(x, np.ndarray):
        return np.pad(x, widths, constant_values=value)
    return jnp.pad(x, widths, constant_values=value)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"

"""Graph datasets matching the paper's Table II profiles.

The container is offline, so we generate synthetic graphs with the exact
node/edge/feature-dimension counts of Cora, Citeseer and Pubmed (Table II)
using a preferential-attachment degree profile (citation networks are
power-law). Features are dense random vectors; labels are uniform over the
standard class counts. All generation is deterministic per seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphProfile:
    name: str
    num_nodes: int
    num_edges: int
    feature_dim: int
    num_classes: int


# Paper Table II.
DATASETS: dict[str, GraphProfile] = {
    "cora": GraphProfile("cora", 2708, 10556, 1433, 7),
    "citeseer": GraphProfile("citeseer", 3327, 9104, 3703, 6),
    "pubmed": GraphProfile("pubmed", 19717, 88648, 500, 3),
}


@dataclasses.dataclass
class GraphData:
    profile: GraphProfile
    edges: np.ndarray      # (E, 2) int64 (src, dst), both directions present
    features: np.ndarray   # (N, F) float32
    labels: np.ndarray     # (N,) int32
    train_mask: np.ndarray # (N,) bool

    @property
    def size_mb(self) -> float:
        return self.features.nbytes / 2 ** 20


def _preferential_attachment_edges(n: int, e_target: int, rng: np.random.Generator) -> np.ndarray:
    """Undirected preferential-attachment edge list with ~e_target/2 unique
    undirected edges (returned with both directions, ≈ e_target directed)."""
    m = max(1, e_target // (2 * n))  # edges added per new node
    extra = e_target // 2 - m * (n - m)
    # classic BA via repeated-node sampling
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    edges = []
    for v in range(m, n):
        for t in set(targets):
            edges.append((v, t))
            repeated.extend([v, t])
        # next targets: preferential sample
        idx = rng.integers(0, len(repeated), size=m)
        targets = [repeated[i] for i in idx]
    # top up to the target count with preferential random pairs
    repeated_arr = np.array(repeated)
    while extra > 0:
        k = min(extra, 4096)
        a = repeated_arr[rng.integers(0, len(repeated_arr), size=k)]
        b = rng.integers(0, n, size=k)
        mask = a != b
        for u, v in zip(a[mask], b[mask]):
            edges.append((int(u), int(v)))
        extra -= int(mask.sum())
    e = np.array(edges, dtype=np.int64)
    # dedupe undirected, then emit both directions
    und = np.unique(np.sort(e, axis=1), axis=0)
    return np.concatenate([und, und[:, ::-1]], axis=0)


def make_dataset(name: str, *, seed: int = 0, scale: float = 1.0) -> GraphData:
    """Generate a synthetic dataset with the given Table-II profile.

    ``scale`` multiplies node/edge counts (used by the large-graph training
    example); feature_dim is kept.
    """
    prof = DATASETS[name]
    if scale != 1.0:
        prof = GraphProfile(
            f"{name}-x{scale:g}",
            int(prof.num_nodes * scale),
            int(prof.num_edges * scale),
            prof.feature_dim,
            prof.num_classes,
        )
    rng = np.random.default_rng(seed)
    edges = _preferential_attachment_edges(prof.num_nodes, prof.num_edges, rng)
    feats = rng.standard_normal((prof.num_nodes, prof.feature_dim), dtype=np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True) + 1e-6
    labels = rng.integers(0, prof.num_classes, size=prof.num_nodes).astype(np.int32)
    # plant weak class signal so training has something to learn
    planted = rng.standard_normal((prof.num_classes, prof.feature_dim), dtype=np.float32)
    feats += 0.5 * planted[labels] / np.sqrt(prof.feature_dim)
    train_mask = rng.random(prof.num_nodes) < 0.6
    return GraphData(prof, edges, feats, labels, train_mask)

"""Graph datasets matching the paper's Table II profiles.

The container is offline, so we generate synthetic graphs with the exact
node/edge/feature-dimension counts of Cora, Citeseer and Pubmed (Table II)
using a preferential-attachment degree profile (citation networks are
power-law). Features are dense random vectors; labels are uniform over the
standard class counts. All generation is deterministic per seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphProfile:
    name: str
    num_nodes: int
    num_edges: int
    feature_dim: int
    num_classes: int


# Paper Table II: the evaluation set every paper-table benchmark sweeps.
TABLE2_DATASETS: dict[str, GraphProfile] = {
    "cora": GraphProfile("cora", 2708, 10556, 1433, 7),
    "citeseer": GraphProfile("citeseer", 3327, 9104, 3703, 6),
    "pubmed": GraphProfile("pubmed", 19717, 88648, 500, 3),
}

# Large-graph regime (§VI scaling discussion): a Reddit-scale profile
# (232,965 posts / ~114.6M directed edges / 602 features / 41 classes).
# Kept out of TABLE2_DATASETS so paper-table averages stay comparable to
# the paper's three-dataset numbers.
LARGE_DATASETS: dict[str, GraphProfile] = {
    "reddit": GraphProfile("reddit", 232965, 114615892, 602, 41),
}

# Everything loadable by name via make_dataset/load.
DATASETS: dict[str, GraphProfile] = {**TABLE2_DATASETS, **LARGE_DATASETS}

# Above this many target edges the O(N·m) pure-python BA loop is too slow;
# switch to the vectorized power-law sampler.
_LARGE_GRAPH_EDGES = 1_000_000


@dataclasses.dataclass
class GraphData:
    profile: GraphProfile
    edges: np.ndarray      # (E, 2) int64 (src, dst), both directions present
    features: np.ndarray   # (N, F) float32
    labels: np.ndarray     # (N,) int32
    train_mask: np.ndarray # (N,) bool

    @property
    def size_mb(self) -> float:
        return self.features.nbytes / 2 ** 20


def _preferential_attachment_edges(n: int, e_target: int, rng: np.random.Generator) -> np.ndarray:
    """Undirected preferential-attachment edge list with ~e_target/2 unique
    undirected edges (returned with both directions, ≈ e_target directed)."""
    # edges added per new node; clamped so the m seed nodes (and every
    # sampled id) stay inside [0, n) even for very dense scaled profiles
    m = max(1, min(e_target // (2 * n), n - 1))
    extra = e_target // 2 - m * (n - m)
    # classic BA via repeated-node sampling
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    edges = []
    for v in range(m, n):
        for t in set(targets):
            edges.append((v, t))
            repeated.extend([v, t])
        # next targets: preferential sample
        idx = rng.integers(0, len(repeated), size=m)
        targets = [repeated[i] for i in idx]
    # top up to the target count with preferential random pairs
    repeated_arr = np.array(repeated)
    while extra > 0:
        k = min(extra, 4096)
        a = repeated_arr[rng.integers(0, len(repeated_arr), size=k)]
        b = rng.integers(0, n, size=k)
        mask = a != b
        for u, v in zip(a[mask], b[mask]):
            edges.append((int(u), int(v)))
        extra -= int(mask.sum())
    e = np.array(edges, dtype=np.int64)
    # dedupe undirected, then emit both directions
    und = np.unique(np.sort(e, axis=1), axis=0)
    return np.concatenate([und, und[:, ::-1]], axis=0)


def _powerlaw_edges(n: int, e_target: int, rng: np.random.Generator) -> np.ndarray:
    """Vectorized power-law edge sampler for large (reddit-scale) graphs.

    The O(N·m) python BA loop above is fine for citation-network sizes but
    takes minutes at 10⁸ edges. Here sources are drawn from a Zipf-like
    rank distribution (heavy-tailed out-degree, matching social graphs)
    and destinations uniformly; duplicates are deduped and the undirected
    edge set emitted in both directions, like the BA path.
    """
    want = e_target // 2
    # rank weights ~ 1/(rank+1)^0.8: heavy tail without a single mega-hub
    ranks = np.arange(n, dtype=np.float64)
    w = 1.0 / (ranks + 1.0) ** 0.8
    w /= w.sum()
    perm = rng.permutation(n)          # decouple node id from degree rank
    # dedupe on scalar keys u*n+v (1-D unique is far cheaper than 2-D) and
    # resample until the unique undirected target is hit (the heavy tail
    # makes hub pairs collide often); uniform top-up after a few rounds
    # guarantees convergence even for very dense scaled profiles
    keys = np.empty(0, dtype=np.int64)
    it = stalls = 0
    while len(keys) < want and stalls < 3:
        short = want - len(keys)
        k = int(min(max(short * 1.4, 1 << 14), 1 << 23))
        if it < 4:
            src = perm[rng.choice(n, size=k, p=w)]
        else:
            src = rng.integers(0, n, size=k)
        dst = rng.integers(0, n, size=k)
        lo, hi = np.minimum(src, dst), np.maximum(src, dst)
        new = np.unique(lo[src != dst] * n + hi[src != dst])
        fresh = new[~np.isin(new, keys, assume_unique=True)]
        # a near-saturated pair space yields ever-fewer fresh keys; three
        # low-yield rounds in a row means the target is out of reach
        stalls = stalls + 1 if len(fresh) < max(k // 100, 1) else 0
        keys = np.concatenate([keys, fresh])
        keys.sort()
        it += 1
    if len(keys) < want:
        import warnings
        warnings.warn(
            f"power-law generator saturated at {len(keys)} of {want} unique "
            f"undirected edges for n={n}; graph will be short of the profile")
    if len(keys) > want:
        # random subsample: the key list is sorted, so a prefix slice would
        # systematically disconnect the high-id node range
        keys = keys[rng.permutation(len(keys))[:want]]
    und = np.stack([keys // n, keys % n], axis=1)
    return np.concatenate([und, und[:, ::-1]], axis=0)


def make_dataset(name: str, *, seed: int = 0, scale: float = 1.0) -> GraphData:
    """Generate a synthetic dataset with the given Table-II profile.

    ``scale`` multiplies node/edge counts (used by the large-graph training
    example); feature_dim is kept.
    """
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; available: "
                       f"{sorted(DATASETS)}")
    prof = DATASETS[name]
    if scale != 1.0:
        prof = GraphProfile(
            f"{name}-x{scale:g}",
            int(prof.num_nodes * scale),
            int(prof.num_edges * scale),
            prof.feature_dim,
            prof.num_classes,
        )
    rng = np.random.default_rng(seed)
    if prof.num_edges > _LARGE_GRAPH_EDGES:
        edges = _powerlaw_edges(prof.num_nodes, prof.num_edges, rng)
    else:
        edges = _preferential_attachment_edges(prof.num_nodes, prof.num_edges, rng)
    feats = rng.standard_normal((prof.num_nodes, prof.feature_dim), dtype=np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True) + 1e-6
    labels = rng.integers(0, prof.num_classes, size=prof.num_nodes).astype(np.int32)
    # plant weak class signal so training has something to learn
    planted = rng.standard_normal((prof.num_classes, prof.feature_dim), dtype=np.float32)
    feats += 0.5 * planted[labels] / np.sqrt(prof.feature_dim)
    train_mask = rng.random(prof.num_nodes) < 0.6
    return GraphData(prof, edges, feats, labels, train_mask)


def load(name: str, seed: int = 0, *, scale: float = 1.0
         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One-call loader: ``features, labels, edges = load("cora", seed)``.

    Thin convenience over :func:`make_dataset` for callers (serving,
    benchmarks, notebooks) that only need the three arrays. ``scale``
    shrinks node/edge counts proportionally — the reddit profile at
    scale=1.0 generates ~115M directed edges, so scale it down for
    CPU smoke runs.
    """
    ds = make_dataset(name, seed=seed, scale=scale)
    return ds.features, ds.labels, ds.edges

"""Vectorized neighbor sampling for mini-batch GNN training.

GraphSAGE-style layer-wise neighbor sampling: each step draws a batch of
seed nodes and, per layer, up to ``fanout[l]`` in-neighbors of the current
frontier (with replacement, the standard estimator), then relabels the
union into a **fixed-size** local id space. The fixed budget is the point:
every sampled subgraph shards to the same (S, n) grid and pads its edge
lists to the same cap, so the training step jits once and every later step
reuses the trace.

Sampling is deterministic per ``(seed, step)`` — the train loop's
data-by-step resume contract (checkpoint at step k, resume, and the
sampler replays the exact batches an uninterrupted run would have seen).

All sampling is numpy-vectorized (CSR gather + modular indexing); there
is no per-node Python loop, so reddit-scale frontiers stay cheap.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SubgraphBatch:
    """One sampled, locally-relabeled subgraph with fixed shapes.

    ``nodes`` maps local id -> global id for the first ``num_real`` slots;
    padding slots repeat node 0 but are isolated (no edges) and masked out
    of both the loss (``seed_mask``) and feature gather (``node_valid``).
    """

    nodes: np.ndarray        # (budget,) int64 global ids (padded)
    node_valid: np.ndarray   # (budget,) bool — real (non-padding) slots
    seed_mask: np.ndarray    # (budget,) bool — loss nodes (the seeds)
    edges: np.ndarray        # (E, 2) int64 LOCAL (src, dst), deduplicated
    num_real: int            # real node count before padding


class NeighborSampler:
    """Layer-wise in-neighbor sampler over a fixed node budget.

    Args:
      edges: (E, 2) global (src, dst) edge list (aggregation pulls along
        src -> dst, so we sample *in*-neighbors of the frontier).
      num_nodes: N.
      batch_nodes: seeds per step (the loss nodes).
      fanout: per-layer neighbor sample counts, outermost layer first —
        ``(10, 5)`` samples 10 in-neighbors per seed, then 5 per sampled
        neighbor.
      seed_ids: population the seeds are drawn from (e.g. the train-mask
        node ids); default all nodes.
      budget: fixed local node count; default the worst case
        ``batch_nodes * (1 + f1 + f1*f2 + ...)`` capped at ``num_nodes``.
      seed: RNG stream id (pairs with the step for determinism).
    """

    def __init__(self, edges: np.ndarray, num_nodes: int, *,
                 batch_nodes: int, fanout: tuple[int, ...] = (10, 5),
                 seed_ids: np.ndarray | None = None, budget: int | None = None,
                 seed: int = 0):
        edges = np.asarray(edges, dtype=np.int64)
        self.num_nodes = int(num_nodes)
        self.batch_nodes = int(batch_nodes)
        self.fanout = tuple(int(f) for f in fanout)
        if not self.fanout or any(f < 1 for f in self.fanout):
            raise ValueError(f"fanout needs >=1 per layer, got {fanout}")
        self.seed = int(seed)
        self.seed_ids = (np.arange(num_nodes, dtype=np.int64)
                         if seed_ids is None
                         else np.asarray(seed_ids, dtype=np.int64))
        if self.seed_ids.size == 0:
            raise ValueError("seed_ids is empty")
        if budget is None:
            per_seed = 1
            budget = self.batch_nodes
            for f in self.fanout:
                per_seed *= f
                budget += self.batch_nodes * per_seed
            budget = min(budget, self.num_nodes)
        self.budget = max(int(budget), self.batch_nodes)
        # worst-case deduplicated subgraph edges: each hop keeps at most
        # (kept frontier <= budget) * fanout[l] unique edges, plus a self
        # loop per slot (shard_graph may add them). sum(fanout), not
        # max(fanout): with the budget clamped at num_nodes every hop can
        # contribute its full quota between kept nodes.
        self.edge_cap = self.budget * (sum(self.fanout) + 1)

        # CSR over incoming edges: for node v, its in-neighbor sources are
        # src_sorted[indptr[v]:indptr[v+1]]
        order = np.argsort(edges[:, 1], kind="stable")
        self._src_sorted = np.ascontiguousarray(edges[order, 0])
        dst_sorted = edges[order, 1]
        self._indptr = np.searchsorted(dst_sorted,
                                       np.arange(self.num_nodes + 1))

    def _sample_in_neighbors(self, frontier: np.ndarray, f: int,
                             rng: np.random.Generator
                             ) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) global pairs: up to f in-neighbors per frontier node,
        sampled with replacement, fully vectorized."""
        n_edges = self._src_sorted.size
        if n_edges == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        start = self._indptr[frontier]
        cnt = self._indptr[frontier + 1] - start
        draw = rng.integers(0, np.iinfo(np.int64).max,
                            size=(frontier.size, f))
        idx = draw % np.maximum(cnt, 1)[:, None]
        # zero-in-degree frontier nodes are dropped by `keep` below, but
        # their start offset can sit at E (all edge dsts < node id), so
        # the gather index must be clamped BEFORE it is dereferenced
        gather = np.minimum(start[:, None] + idx, n_edges - 1)
        src = self._src_sorted[gather]                        # (k, f)
        dst = np.broadcast_to(frontier[:, None], src.shape)
        keep = np.broadcast_to((cnt > 0)[:, None], src.shape)
        return src[keep], dst[keep]

    def sample(self, step: int) -> SubgraphBatch:
        """Deterministic batch for one step (resume-safe)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(step)]))
        replace = self.seed_ids.size < self.batch_nodes
        seeds = rng.choice(self.seed_ids, size=self.batch_nodes,
                           replace=replace)
        # always dedupe: a duplicated seed would own a second local slot
        # with NO edges (the relabel lookup maps the global id to one
        # slot), silently training the loss on un-aggregated logits
        seeds = np.unique(seeds)
        frontier = seeds
        srcs, dsts = [], []
        for f in self.fanout:
            s, d = self._sample_in_neighbors(frontier, f, rng)
            srcs.append(s)
            dsts.append(d)
            frontier = np.unique(s)
        src = np.concatenate(srcs) if srcs else np.empty(0, np.int64)
        dst = np.concatenate(dsts) if dsts else np.empty(0, np.int64)

        # local id space: seeds first (so seed_mask is a prefix), then the
        # sampled closure, cropped to the budget (drop non-seed overflow —
        # a RANDOM subset: setdiff1d is sorted, so a prefix crop would
        # exclude high-id neighbors from every batch)
        rest = np.setdiff1d(np.concatenate([src, dst]), seeds)
        if seeds.size + rest.size > self.budget:
            rest = rest[rng.permutation(rest.size)
                        [: self.budget - seeds.size]]
        nodes = np.concatenate([seeds, rest])
        num_real = nodes.size

        lookup = np.full(self.num_nodes, -1, dtype=np.int64)
        lookup[nodes] = np.arange(num_real)
        ls, ld = lookup[src], lookup[dst]
        keep = (ls >= 0) & (ld >= 0)
        e_local = np.stack([ls[keep], ld[keep]], axis=1)
        if e_local.size:
            e_local = np.unique(e_local, axis=0)
        if e_local.shape[0] > self.edge_cap:
            # cannot happen with the sum(fanout) cap above; if a future
            # cap change reintroduces it, drop a random subset (a sorted
            # prefix crop would systematically silence high-id sources)
            import warnings
            warnings.warn(
                f"sampled subgraph exceeded edge_cap "
                f"({e_local.shape[0]} > {self.edge_cap}); dropping a "
                f"random subset")
            e_local = e_local[rng.permutation(e_local.shape[0])
                              [: self.edge_cap]]

        pad = self.budget - num_real
        nodes_padded = np.concatenate(
            [nodes, np.zeros(pad, np.int64)]) if pad else nodes
        node_valid = np.arange(self.budget) < num_real
        seed_mask = np.arange(self.budget) < seeds.size
        return SubgraphBatch(nodes=nodes_padded, node_valid=node_valid,
                             seed_mask=seed_mask, edges=e_local,
                             num_real=num_real)

from repro.graphs.datasets import (DATASETS, LARGE_DATASETS, TABLE2_DATASETS,
                                   GraphData, load, make_dataset)
from repro.graphs.sampler import NeighborSampler, SubgraphBatch

__all__ = ["DATASETS", "LARGE_DATASETS", "TABLE2_DATASETS", "GraphData",
           "load", "make_dataset", "NeighborSampler", "SubgraphBatch"]

from repro.graphs.datasets import (DATASETS, LARGE_DATASETS, TABLE2_DATASETS,
                                   GraphData, load, make_dataset)

__all__ = ["DATASETS", "LARGE_DATASETS", "TABLE2_DATASETS", "GraphData",
           "load", "make_dataset"]

from repro.graphs.datasets import (DATASETS, LARGE_DATASETS,  # noqa: F401
                                   TABLE2_DATASETS, GraphData, load,
                                   make_dataset)

from repro.graphs.datasets import DATASETS, GraphData, make_dataset  # noqa: F401

"""Distributed graph partitioning: map the 2-D shard grid onto the mesh.

Cluster-scale version of the paper's parallelism (DESIGN.md §2): shard-
grid ROWS (destination ranges) ride the ``data`` axis — each data group
owns the aggregation of its destination nodes (inter-node parallelism);
the FEATURE axis rides ``model`` — the distributed generalization of
dimension-blocking (intra-node parallelism). The plan below computes which
source-shard features each data group must receive per step: exactly the
paper's Table-I traffic, with DRAM reads become cross-device transfers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sharding import ShardedGraph


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    n_data: int                 # data-axis size
    rows_per_group: int         # dst shard rows per data group
    # comm_matrix[g_dst, g_src] = edges whose sources live on g_src and
    # destinations on g_dst (off-diagonal = cross-group transfers)
    comm_matrix: np.ndarray

    @property
    def cross_group_edge_frac(self) -> float:
        total = self.comm_matrix.sum()
        if total == 0:
            return 0.0
        return float(1.0 - np.trace(self.comm_matrix) / total)

    def transfer_bytes_per_layer(self, feature_dim: int,
                                 dtype_bytes: int = 2) -> float:
        """Upper bound: every cross-group edge pulls one source feature
        row (dedup within a group is shard-level, handled on-device)."""
        off = self.comm_matrix.sum() - np.trace(self.comm_matrix)
        return float(off) * feature_dim * dtype_bytes


def partition_graph(sg: ShardedGraph, n_data: int) -> PartitionPlan:
    """Assign dst-shard rows round-robin-contiguously to data groups and
    build the inter-group communication matrix."""
    rows_per_group = -(-sg.S // n_data)
    occ = sg.occupancy  # (S, S) edges per (dst, src) shard
    comm = np.zeros((n_data, n_data), dtype=np.float64)
    for i in range(sg.S):
        gi = min(i // rows_per_group, n_data - 1)
        for j in range(sg.S):
            gj = min(j // rows_per_group, n_data - 1)
            comm[gi, gj] += occ[i, j]
    return PartitionPlan(n_data, rows_per_group, comm)


def balance_report(sg: ShardedGraph, n_data: int) -> dict:
    """Load balance: edges per data group (the straggler predictor)."""
    plan = partition_graph(sg, n_data)
    per_group = plan.comm_matrix.sum(axis=1)
    return {
        "edges_per_group_mean": float(per_group.mean()),
        "edges_per_group_max": float(per_group.max()),
        "imbalance": float(per_group.max() / max(per_group.mean(), 1.0)),
        "cross_group_edge_frac": plan.cross_group_edge_frac,
    }

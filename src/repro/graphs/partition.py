"""Distributed graph partitioning: map the 2-D shard grid onto the mesh.

Cluster-scale version of the paper's parallelism (DESIGN.md §2): shard-
grid ROWS (destination ranges) ride the ``data`` axis — each data group
owns the aggregation of its destination nodes (inter-node parallelism);
the FEATURE axis rides ``model`` — the distributed generalization of
dimension-blocking (intra-node parallelism). The plan below computes which
source-shard features each data group must receive per step: exactly the
paper's Table-I traffic, with DRAM reads become cross-device transfers.

``dist/gnn.py`` executes exactly this decomposition under ``shard_map``
(``pad=True`` gives the equal row groups the SPMD program needs) and
verifies its measured all-gather volume against the plan's models.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    n_data: int                 # data-axis size
    rows_per_group: int         # max dst shard rows any data group owns
    # comm_matrix[g_dst, g_src] = edges whose sources live on g_src and
    # destinations on g_dst (off-diagonal = cross-group transfers)
    comm_matrix: np.ndarray
    # dst shard rows actually owned per group (balanced split: sizes
    # differ by at most one; an equal padded split may trail smaller)
    group_sizes: tuple[int, ...] = ()

    @property
    def cross_group_edge_frac(self) -> float:
        total = self.comm_matrix.sum()
        if total == 0:
            return 0.0
        return float(1.0 - np.trace(self.comm_matrix) / total)

    def transfer_bytes_per_layer(self, feature_dim: int,
                                 dtype_bytes: int = 2) -> float:
        """Per-edge pull model: every cross-group edge pulls one source
        feature row (dedup within a group is shard-level, handled
        on-device). An upper bound for an edge-driven fetch schedule."""
        off = self.comm_matrix.sum() - np.trace(self.comm_matrix)
        return float(off) * feature_dim * dtype_bytes

    def allgather_bytes_per_layer(self, feature_dim: int, shard_n: int,
                                  dtype_bytes: int = 2) -> float:
        """Broadcast (all-gather) model: what the shard_map executable in
        dist/gnn.py actually moves per layer — every group broadcasts its
        ``rows_per_group`` padded rows to every other group, so total wire
        bytes are ``(n_data - 1) · n_data · rows_per_group · shard_n ·
        feature_dim`` (padded rows included: the SPMD program ships
        them)."""
        total_rows = self.n_data * self.rows_per_group
        return float((self.n_data - 1) * total_rows * shard_n
                     * feature_dim * dtype_bytes)


def partition_graph(sg, n_data: int, *, pad: bool = False) -> PartitionPlan:
    """Assign contiguous dst-shard row ranges to data groups and build the
    inter-group communication matrix.

    ``sg`` is anything with ``.S`` (grid width) and ``.occupancy`` ((S, S)
    edges per (dst, src) shard): a ``core.sharding.ShardedGraph`` or a
    ``core.engines.GraphTensors``.

    ``pad=False`` (default) splits the S rows balanced-contiguously
    (``np.array_split`` semantics: sizes differ by at most one, no group
    is left empty while another holds two extra — the old ceil-division
    assignment produced empty trailing groups, e.g. S=4, n_data=3 gave
    (2, 2, 0)). ``pad=True`` splits ceil(S / n_data) rows to every group
    as if the grid were zero-padded to a multiple of n_data — the equal
    split the shard_map executable needs (trailing groups own fewer real
    rows).
    """
    S = int(sg.S)
    occ = np.asarray(sg.occupancy, dtype=np.float64)
    if pad:
        rows_per_group = -(-S // n_data)
        group_of = np.minimum(np.arange(S) // rows_per_group, n_data - 1)
    else:
        splits = np.array_split(np.arange(S), n_data)
        group_of = np.empty(S, dtype=np.int64)
        for g, rows in enumerate(splits):
            group_of[rows] = g
        rows_per_group = max((len(rows) for rows in splits), default=0)
    sizes = np.bincount(group_of, minlength=n_data) if S else \
        np.zeros(n_data, dtype=np.int64)
    # comm = G · occ · Gᵀ with G the (n_data, S) group-indicator matrix —
    # one matmul pair instead of the former O(S²) Python double loop
    ind = np.zeros((n_data, S), dtype=np.float64)
    if S:
        ind[group_of, np.arange(S)] = 1.0
    comm = ind @ occ @ ind.T
    return PartitionPlan(n_data, int(rows_per_group), comm,
                         group_sizes=tuple(int(s) for s in sizes))


def balance_report(sg, n_data: int) -> dict:
    """Load balance: edges per data group (the straggler predictor).

    Uses the balanced (array_split) assignment, so the mean is taken over
    groups that actually own rows — no empty trailing groups diluting the
    imbalance ratio."""
    plan = partition_graph(sg, n_data)
    per_group = plan.comm_matrix.sum(axis=1)
    return {
        "edges_per_group_mean": float(per_group.mean()),
        "edges_per_group_max": float(per_group.max()),
        "imbalance": float(per_group.max() / max(per_group.mean(), 1.0)),
        "cross_group_edge_frac": plan.cross_group_edge_frac,
        "group_sizes": plan.group_sizes,
    }

"""repro.analyze — static analysis over the artifacts the stack produces.

Five passes, each decidable before (or at) compile time, long before a
bad config burns a measurement timeout or a hot-path sync backs a queue
up:

  * **retrace**   — jitted entry points must trace once and serve
    forever (:mod:`repro.analyze.jaxpr_lint`, plus the ``jax.jit``-in-
    loop source rule in :mod:`repro.analyze.ast_lint`);
  * **dtype**     — jaxpr walk for f64 promotion, weak-typed entry
    arguments, int32-overflow-scale arrays (:mod:`.jaxpr_lint`);
  * **host-sync** — AST lint forbidding device→host syncs in the
    serving/runtime/kernels hot paths (:mod:`.ast_lint`);
  * **plan**      — LayerPlan/candidate legality + static pruning for
    the autotuner (:mod:`.plan_lint`);
  * **comm**      — compiled-HLO collective bytes vs the PartitionPlan
    model for any mesh compile (:mod:`.hlo_lint`).

Entry points: :func:`analyze_executable` (what
``runtime.compile(analyze=...)`` calls), :func:`preflight` (what
``Server.start(analyze=...)`` calls), and the ``python -m
repro.launch.analyze`` CLI that runs everything over the repo as the CI
gate.
"""
from __future__ import annotations

import time

from repro.analyze import ast_lint, hlo_lint, jaxpr_lint, plan_lint
from repro.analyze.report import (PASSES, SEVERITIES, AnalysisError, Finding,
                                  Report, severity_rank)

__all__ = [
    "Finding", "Report", "AnalysisError", "SEVERITIES", "PASSES",
    "severity_rank", "analyze_executable", "preflight",
    "ast_lint", "jaxpr_lint", "plan_lint", "hlo_lint",
]


def analyze_executable(exe, *, probe: bool = False,
                       rtol: float = 0.02) -> Report:
    """All compile-time passes over one compiled Executable.

    ``probe`` additionally drives the jitted entry points (full-graph
    forward twice, node batches across pad buckets) and reads the jit
    trace caches — a real dynamic retrace oracle, at the cost of real
    forwards. The host-sync pass is source-level and repo-wide, so it
    runs in the CLI/CI gate, not per compile.
    """
    report = Report()

    t0 = time.perf_counter()
    report.extend(jaxpr_lint.check_executable(exe, probe=probe))
    report.timings_ms["retrace+dtype"] = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    report.extend(plan_lint.check_model_plan(
        exe.plan, backend_name=exe.backend_name))
    report.timings_ms["plan"] = (time.perf_counter() - t0) * 1e3

    if hasattr(exe, "comm_stats"):
        t0 = time.perf_counter()
        report.extend(hlo_lint.check_sharded_executable(exe, rtol=rtol))
        report.timings_ms["comm"] = (time.perf_counter() - t0) * 1e3
    else:
        report.skipped["comm"] = \
            "single-device compile (no mesh): nothing on the wire"
    report.skipped["host-sync"] = \
        "source-level pass; run `python -m repro.launch.analyze`"
    return report


def preflight(engine=None, *, probe: bool = False,
              rtol: float = 0.02) -> Report:
    """Serving-startup analysis: host-sync lint over the deployed hot
    paths, plus every pass over each Executable the engine has already
    compiled (GNN engines compile lazily — pairs compiled after startup
    are covered by ``runtime.compile(analyze=...)``)."""
    report = Report()
    t0 = time.perf_counter()
    report.extend(ast_lint.lint_hot_paths())
    report.timings_ms["host-sync"] = (time.perf_counter() - t0) * 1e3

    exes = getattr(engine, "_executables", None)
    if exes:
        for exe in list(exes.values()):
            report.merge(analyze_executable(exe, probe=probe, rtol=rtol))
        # per-exe host-sync skip notes are superseded: the pass ran above
        report.skipped.pop("host-sync", None)
    elif engine is not None:
        report.skipped["plan"] = report.skipped["retrace"] = \
            "no compiled executables yet (engine compiles lazily)"
    return report

"""Host-sync lint: AST pass forbidding device→host syncs in hot paths.

A serving or training hot path must never silently block on device
values: ``.item()``, ``float(jnp.max(...))``, ``np.asarray(device_arr)``
and ``block_until_ready`` each stall the dispatch pipeline for a full
device round trip — the difference between a queue that drains and one
that backs up. The measurement harness (``tune/measure.py``) and the
benchmarks do this *on purpose* (timing needs a fence), so they are
allow-listed; anything else under ``serving/``, ``runtime/`` and
``kernels/`` is a finding.

Rules:

  * **HS001** (error)   — ``x.item()``: per-element device sync.
  * **HS002** (error)   — ``jax.block_until_ready(x)`` /
    ``x.block_until_ready()``: an explicit fence outside a benchmark.
  * **HS003** (warning) — ``float(...)`` / ``int(...)`` / ``bool(...)``
    around a jnp/jax reduction call (``jnp.max``, ``jnp.sum``, …): pulls
    a scalar off the device. (``float(jnp.finfo(...).max)`` and other
    metadata accessors are *not* flagged — only array-producing ops.)
  * **HS004** (warning) — ``jax.device_get(...)`` or
    ``np.asarray(<jnp/jax call>)``: whole-array device→host transfer.
  * **RT101** (error)   — ``jax.jit(...)`` inside a ``for``/``while``
    body: every iteration builds a fresh jitted callable with an empty
    cache, i.e. a guaranteed per-iteration retrace. (Reported under the
    retrace pass; it is a *source* pattern, so it lives with the AST
    walker.)

Suppression: a comment containing ``analyze: allow(HS004)`` (or
``allow(host-sync)`` for the whole pass) on the offending line.
"""
from __future__ import annotations

import ast
import pathlib
import re

from repro.analyze.report import Finding

PASS = "host-sync"

# jnp/jax array-producing reductions whose float()/int() coercion is a
# device sync; metadata helpers (finfo, iinfo, shape, ndim, size) are not
_REDUCTIONS = frozenset({
    "max", "min", "sum", "mean", "prod", "argmax", "argmin", "all", "any",
    "median", "norm", "dot", "vdot", "count_nonzero", "nanmax", "nanmin",
    "nansum", "nanmean",
})

# module aliases treated as "the jax family" when they head an attribute
# chain: jnp.max(...), jax.numpy.max(...), jax.lax.reduce(...)
_JAX_ROOTS = frozenset({"jnp", "jax", "lax"})

# hot-path packages, relative to src/repro
HOT_PATHS = ("serving", "runtime", "kernels")

# path substrings exempt from the pass (measurement needs fences)
DEFAULT_ALLOW = ("tune/measure.py", "benchmarks/")

_ALLOW_RE = re.compile(r"analyze:\s*allow\(([A-Za-z0-9_,\s-]+)\)")


def _suppressed(source_line: str, rule: str, pass_name: str) -> bool:
    m = _ALLOW_RE.search(source_line)
    if not m:
        return False
    tokens = {t.strip() for t in m.group(1).split(",")}
    return rule in tokens or pass_name in tokens


def _attr_chain(node: ast.AST) -> list[str]:
    """['jax', 'numpy', 'max'] for jax.numpy.max; [] when not a chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        return []
    return parts[::-1]


def _is_jax_reduction_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = _attr_chain(node.func)
    return (len(chain) >= 2 and chain[0] in _JAX_ROOTS
            and chain[-1] in _REDUCTIONS)


def _contains_jax_call(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = _attr_chain(sub.func)
            if chain and chain[0] in _JAX_ROOTS:
                return True
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, lines: list[str]):
        self.path = path
        self.lines = lines
        self.findings: list[Finding] = []
        self._loop_depth = 0

    def _emit(self, rule: str, severity: str, node: ast.AST, msg: str,
              pass_name: str = PASS) -> None:
        line = self.lines[node.lineno - 1] if \
            0 < node.lineno <= len(self.lines) else ""
        if _suppressed(line, rule, pass_name):
            return
        self.findings.append(Finding(
            rule=rule, severity=severity, pass_name=pass_name, message=msg,
            location=f"{self.path}:{node.lineno}"))

    # -- loops gate RT101 --------------------------------------------------

    def visit_For(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_While(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    # -- calls -------------------------------------------------------------

    def visit_Call(self, node: ast.Call):
        chain = _attr_chain(node.func)

        if isinstance(node.func, ast.Attribute):
            if node.func.attr == "item" and not node.args:
                self._emit("HS001", "error", node,
                           ".item() forces a per-element device sync; "
                           "batch the transfer (device_get once) outside "
                           "the hot path")
            if node.func.attr == "block_until_ready":
                self._emit("HS002", "error", node,
                           "block_until_ready is a device fence; only "
                           "measurement harnesses may block the hot path")

        if chain[-1:] == ["device_get"] and chain[0] in _JAX_ROOTS:
            self._emit("HS004", "warning", node,
                       "jax.device_get transfers the whole array to host; "
                       "keep hot-path values on device (or annotate the "
                       "deliberate materialization point)")

        if isinstance(node.func, ast.Name) and \
                node.func.id in ("float", "int", "bool") and node.args:
            if _is_jax_reduction_call(node.args[0]):
                self._emit("HS003", "warning", node,
                           f"{node.func.id}() around a device reduction "
                           f"syncs per call; hoist to host data or keep "
                           f"the comparison on device")

        if chain[-2:] == ["np", "asarray"] or chain[-2:] == ["np", "array"]:
            if node.args and _contains_jax_call(node.args[0]):
                self._emit("HS004", "warning", node,
                           "np.asarray over a jax expression is a hidden "
                           "device→host transfer")

        if chain == ["jax", "jit"] and self._loop_depth > 0:
            self._emit("RT101", "error", node,
                       "jax.jit inside a loop body builds a fresh callable "
                       "(empty cache) every iteration — a guaranteed "
                       "retrace; hoist the jit outside the loop",
                       pass_name="retrace")

        self.generic_visit(node)


def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    """Lint one module's source text. Syntax errors are reported as a
    finding (the analyzer must not crash on a broken tree)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as err:
        return [Finding(rule="HS000", severity="error", pass_name=PASS,
                        message=f"unparseable module: {err}",
                        location=f"{path}:{err.lineno or 0}")]
    v = _Visitor(path, source.splitlines())
    v.visit(tree)
    return v.findings


def lint_paths(roots, *, allow=DEFAULT_ALLOW,
               repo_root: pathlib.Path | None = None) -> list[Finding]:
    """Lint every ``.py`` file under ``roots`` (files or directories),
    skipping paths whose POSIX form contains an ``allow`` substring."""
    out: list[Finding] = []
    for root in roots:
        root = pathlib.Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            posix = f.as_posix()
            if any(a in posix for a in allow):
                continue
            rel = (f.relative_to(repo_root).as_posix()
                   if repo_root and f.is_relative_to(repo_root) else posix)
            out.extend(lint_source(f.read_text(), rel))
    return out


def lint_hot_paths(src_repro: pathlib.Path | None = None) -> list[Finding]:
    """Lint the serving/runtime/kernels hot paths of this checkout."""
    if src_repro is None:
        src_repro = pathlib.Path(__file__).resolve().parent.parent
    roots = [src_repro / p for p in HOT_PATHS]
    return lint_paths([r for r in roots if r.exists()],
                      repo_root=src_repro.parent.parent)

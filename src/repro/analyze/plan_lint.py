"""Plan-legality checker: static constraints over (Layer|Model)Plans.

Every constraint here is decidable from the plan alone — before any
kernel runs (VersaGNN's tiling-legality observation). The autotuner
(:func:`repro.tune.search.candidate_plans`) runs :func:`prune_candidates`
over its search space so doomed configs are rejected for free instead of
burning a measurement timeout each; `runtime.compile(analyze=...)` and
the CLI run :func:`check_model_plan` over the plan actually compiled.

Rules:

  * **PL001** (error)   — feature block outside ``1 <= B <= d_agg``:
    dimension-blocking cannot block more dims than exist.
  * **PL002** (error)   — shard grid inconsistent: ``n < 1`` or
    ``S != ceil(N / n)`` (the forward reshapes (S·n, d); a wrong S either
    drops rows or indexes past the grid).
  * **PL003** (error)   — working set (src block + dst accumulators +
    adjacency block) exceeds the memory budget: the backend's kernel
    scratch for fused plans (pallas: 16 MiB VMEM), the platform's
    on-chip budget otherwise.
  * **PL004** (error)   — ``fused`` on a non-fusable arch: the fused
    aggregate+extract kernel assumes linear aggregation with the dense
    transform after it (gcn only today).
  * **PL005** (error)   — unknown traversal order (Table I defines
    src- and dst-stationary; anything else never reaches a kernel).
  * **PL006** (warning) — activation grid S·n·d_agg past int32 element
    count: flattened int32 indexing wraps at reddit scale.
  * **PL007** (warning) — over half the shard grid is padding
    (S·n >= 2·N): legal, but the kernels spend most of their time on
    zero rows — a smaller n dominates.

Beyond legality, :func:`prune_candidates` also drops candidates that are
*execution-identical* to an earlier one: the runtime forward consumes
only each layer's (B, fused) and the model-level shard_n — n/S/order are
analytic metadata (``runtime/forward.py::_controller``) — so two plans
agreeing on those measure the same program twice.
"""
from __future__ import annotations

import hashlib
import json

from repro.analyze.report import Finding
from repro.gnn.executor import LayerPlan, ModelPlan
from repro.utils import cdiv

PASS = "plan"

_INT32_MAX = 2 ** 31 - 1
_F32 = 4

VALID_ORDERS = frozenset({"src_stationary", "dst_stationary"})
FUSABLE_ARCHS = frozenset({"gcn"})

# kernel-scratch budget for *fused* plans, by backend: the fused kernel
# holds the whole working set in kernel-local memory (TPU VMEM for
# pallas). Backends not listed fall back to the plan's platform budget.
BACKEND_SCRATCH_BYTES: dict[str, int] = {
    "pallas": 16 * 2 ** 20,    # TPU VMEM per core
}


def scratch_budget_bytes(plan: ModelPlan, layer: LayerPlan,
                         backend_name: str | None) -> int:
    if layer.fused and backend_name in BACKEND_SCRATCH_BYTES:
        return BACKEND_SCRATCH_BYTES[backend_name]
    return plan.onchip_bytes


def check_layer(plan: ModelPlan, p: LayerPlan, *,
                backend_name: str | None = None) -> list[Finding]:
    """All plan-legality findings for one layer of ``plan``."""
    out: list[Finding] = []
    loc = f"{plan.arch}/L{p.layer}"
    N = plan.num_nodes

    if not 1 <= p.B <= p.d_agg:
        out.append(Finding(
            rule="PL001", severity="error", pass_name=PASS,
            message=f"feature block B={p.B} outside [1, d_agg={p.d_agg}]; "
                    f"dimension-blocking cannot block more dims than exist",
            location=loc))
    if p.n < 1 or p.S != cdiv(N, max(p.n, 1)):
        out.append(Finding(
            rule="PL002", severity="error", pass_name=PASS,
            message=f"shard grid inconsistent: n={p.n}, S={p.S}, but "
                    f"ceil(N={N} / n) = {cdiv(N, max(p.n, 1))} — the "
                    f"forward would drop rows or index past the grid",
            location=loc))
    budget = scratch_budget_bytes(plan, p, backend_name)
    used = p.onchip_bytes_used()
    if used > budget:
        kind = (f"backend {backend_name!r} kernel scratch" if p.fused
                and backend_name in BACKEND_SCRATCH_BYTES
                else f"platform {plan.platform!r} on-chip budget")
        out.append(Finding(
            rule="PL003", severity="error", pass_name=PASS,
            message=f"working set {used / 2**20:.2f} MiB (2nB + n^2 at "
                    f"n={p.n}, B={p.B}) exceeds {kind} "
                    f"{budget / 2**20:.2f} MiB",
            location=loc))
    if p.fused and plan.arch not in FUSABLE_ARCHS:
        out.append(Finding(
            rule="PL004", severity="error", pass_name=PASS,
            message=f"fused aggregate+extract requires linear aggregation "
                    f"(archs {sorted(FUSABLE_ARCHS)}); {plan.arch!r} "
                    f"must run two-stage",
            location=loc))
    if str(p.order) not in VALID_ORDERS:
        out.append(Finding(
            rule="PL005", severity="error", pass_name=PASS,
            message=f"unknown traversal order {p.order!r}; Table I "
                    f"defines {sorted(VALID_ORDERS)}",
            location=loc))
    if p.S * p.n * p.d_agg > _INT32_MAX:
        out.append(Finding(
            rule="PL006", severity="warning", pass_name=PASS,
            message=f"activation grid S*n*d = "
                    f"{p.S * p.n * p.d_agg:,} elements exceeds int32 — "
                    f"flattened int32 indexing wraps at this scale",
            location=loc))
    if N >= 1 and p.n >= 1 and p.S * p.n >= 2 * N:
        out.append(Finding(
            rule="PL007", severity="warning", pass_name=PASS,
            message=f"padding-dominated grid: S*n = {p.S * p.n} rows for "
                    f"N = {N} nodes (>= 50% padding); a smaller n wastes "
                    f"less kernel time on zero rows",
            location=loc))
    return out


def check_model_plan(plan: ModelPlan, *,
                     backend_name: str | None = None) -> list[Finding]:
    """Plan-legality findings for every layer of one ModelPlan."""
    out: list[Finding] = []
    for p in plan.layers:
        out.extend(check_layer(plan, p, backend_name=backend_name))
    return out


# --------------------------------------------------------------------------
# static pruning for the autotuner
# --------------------------------------------------------------------------

def executed_digest(plan: ModelPlan) -> str:
    """Hash of what the runtime forward *actually consumes*: the
    model-level shard size plus each layer's (B, fused). Plans agreeing
    here run byte-identical programs, whatever their n/S/order metadata
    says (those only shape analytic estimates)."""
    payload = json.dumps(
        [plan.shard_n] + [[p.layer, p.B, p.fused] for p in plan.layers],
        sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def prune_candidates(cands: list[ModelPlan], *,
                     backend_name: str | None = None,
                     ) -> tuple[list[ModelPlan], list[dict]]:
    """Split candidates into (kept, pruned-records).

    Candidate #0 (the analytic plan) is kept unconditionally — it is the
    fallback the tuner must always be able to serve, so policy never
    removes it. Later candidates are pruned when they carry an
    error-severity legality finding, or when their executed configuration
    duplicates an earlier kept candidate. Each pruned record carries
    ``{"index", "reason", "rules", "detail"}`` for the tune report."""
    kept: list[ModelPlan] = []
    pruned: list[dict] = []
    seen: dict[str, int] = {}
    for i, plan in enumerate(cands):
        digest = executed_digest(plan)
        if i == 0:
            kept.append(plan)
            seen[digest] = i
            continue
        errors = [f for f in check_model_plan(plan,
                                              backend_name=backend_name)
                  if f.severity == "error"]
        if errors:
            pruned.append({
                "index": i, "reason": "illegal",
                "rules": sorted({f.rule for f in errors}),
                "detail": errors[0].message})
            continue
        if digest in seen:
            pruned.append({
                "index": i, "reason": "duplicate-execution",
                "rules": [],
                "detail": f"executes identically to candidate "
                          f"#{seen[digest]} (same shard_n and per-layer "
                          f"(B, fused); n/S/order are analytic metadata)"})
            continue
        seen[digest] = i
        kept.append(plan)
    return kept, pruned

"""Retrace + dtype-drift passes over jitted entry points and their jaxprs.

**Retrace pass.** A production jit entry point must trace once and serve
forever; every extra trace is seconds of XLA compile charged to some
unlucky request. The static halves of the pass flag the *causes*
(Python-scalar pytree leaves → weak-typed tracers that retrace when a
typed value arrives; ``jax.jit`` built inside a loop — see
``ast_lint.RT101``); the dynamic half (:func:`trace_stability`) is the
*oracle*: drive the entry point with a representative call sequence and
read the jit cache size — anything above the expected trace count is a
finding, whatever the cause.

**Dtype pass.** Walks a jaxpr (sub-jaxprs included) for

  * f64/c128 values — unintended x64 promotion doubles every buffer and
    silently halves throughput on accelerators,
  * weak-typed entry arguments — the Python-scalar signature that both
    promotes dtypes *and* retraces when a typed array arrives,
  * arrays beyond int32 element count — at reddit-scale node counts a
    flattened int32 index (edge gathers, dense shard grids) wraps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analyze.report import Finding

_INT32_MAX = 2 ** 31 - 1


# --------------------------------------------------------------------------
# retrace
# --------------------------------------------------------------------------

def cache_size(fn) -> int | None:
    """Size of a jitted callable's trace cache; None when ``fn`` does not
    expose one (not a jit wrapper)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:   # pragma: no cover - defensive
        return None


def python_scalar_leaves(tree, *, name: str,
                         pass_name: str = "retrace") -> list[Finding]:
    """RT002: Python int/float/bool leaves in an argument pytree trace as
    weak-typed values — the jit signature changes (and retraces) the
    moment a caller passes a typed array instead, and the weak dtype can
    promote everything it touches."""
    out: list[Finding] = []
    leaves, _ = jax.tree_util.tree_flatten(tree)
    for i, leaf in enumerate(leaves):
        if isinstance(leaf, (bool, int, float)) and \
                not isinstance(leaf, np.generic):
            out.append(Finding(
                rule="RT002", severity="warning", pass_name=pass_name,
                message=f"pytree leaf {i} is a Python "
                        f"{type(leaf).__name__} ({leaf!r}); it traces "
                        f"weak-typed and retraces when a typed array "
                        f"arrives — wrap it in jnp.asarray with an "
                        f"explicit dtype",
                location=name))
    return out


def trace_stability(fn, calls, *, name: str,
                    max_traces: int = 1) -> list[Finding]:
    """RT003: drive a jitted ``fn`` with every args-tuple in ``calls``
    and flag cache growth beyond ``max_traces`` — the dynamic retrace
    oracle (shape-dependent rebinds, scalar closures, donation misses all
    surface here regardless of cause)."""
    before = cache_size(fn)
    if before is None:
        return [Finding(
            rule="RT000", severity="info", pass_name="retrace",
            message="entry point exposes no jit trace cache; retrace "
                    "probe skipped", location=name)]
    for args in calls:
        jax.block_until_ready(fn(*args))
    after = cache_size(fn)
    if after is not None and after > max_traces:
        return [Finding(
            rule="RT003", severity="error", pass_name="retrace",
            message=f"{len(calls)} same-spec calls produced {after} "
                    f"traces (expected <= {max_traces}); a per-request "
                    f"recompile is hiding in this entry point",
            location=name)]
    return []


# --------------------------------------------------------------------------
# dtype drift
# --------------------------------------------------------------------------

def _iter_sub_jaxprs(params: dict):
    from jax.core import ClosedJaxpr, Jaxpr
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for item in vs:
            if isinstance(item, ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, Jaxpr):
                yield item


def _walk_eqns(jaxpr, visit) -> None:
    for eqn in jaxpr.eqns:
        visit(eqn)
        for sub in _iter_sub_jaxprs(eqn.params):
            _walk_eqns(sub, visit)


def _aval_of(var):
    return getattr(var, "aval", None)


def dtype_findings(closed_jaxpr, *, name: str,
                   allow_f64: bool = False) -> list[Finding]:
    """Walk one ClosedJaxpr for the dtype-drift rules (see module
    docstring): DT001 f64/c128 values, DT002 weak-typed entry arguments,
    DT003 arrays past int32 element count."""
    out: list[Finding] = []
    jaxpr = closed_jaxpr.jaxpr

    for i, var in enumerate(jaxpr.invars):
        aval = _aval_of(var)
        if aval is None or not hasattr(aval, "dtype"):
            continue
        if getattr(aval, "weak_type", False):
            out.append(Finding(
                rule="DT002", severity="warning", pass_name="dtype",
                message=f"entry argument {i} is weak-typed "
                        f"({aval.dtype}); it came from a Python scalar "
                        f"and will both promote dtypes and retrace when "
                        f"a typed array is passed",
                location=name))

    seen_f64: set[str] = set()
    seen_big: set[str] = set()

    def visit(eqn):
        prim = eqn.primitive.name
        for var in (*eqn.invars, *eqn.outvars):
            aval = _aval_of(var)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            dt = np.dtype(aval.dtype)
            if not allow_f64 and dt in (np.dtype(np.float64),
                                        np.dtype(np.complex128)) \
                    and prim not in seen_f64:
                seen_f64.add(prim)
                out.append(Finding(
                    rule="DT001", severity="error", pass_name="dtype",
                    message=f"{dt} value flows through '{prim}' — "
                            f"unintended x64 promotion doubles every "
                            f"buffer it touches; pin the input dtype or "
                            f"cast at the boundary",
                    location=name))
            shape = getattr(aval, "shape", ())
            if shape and int(np.prod(shape, dtype=np.int64)) > _INT32_MAX \
                    and prim not in seen_big:
                seen_big.add(prim)
                out.append(Finding(
                    rule="DT003", severity="warning", pass_name="dtype",
                    message=f"'{prim}' touches an array of "
                            f"{int(np.prod(shape, dtype=np.int64)):,} "
                            f"elements (> int32 max); flattened int32 "
                            f"indexing (edge gathers, dense shard grids) "
                            f"wraps at this scale — use int64 indices or "
                            f"shard the tensor",
                    location=name))

    _walk_eqns(jaxpr, visit)
    return out


# --------------------------------------------------------------------------
# Executable-level entry
# --------------------------------------------------------------------------

def _forward_avals(exe):
    """(params-avals, h-aval) matching one compiled Executable."""
    p_avals = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        exe.params)
    h = exe._h_grouped
    if h is not None:
        h_aval = jax.ShapeDtypeStruct(jnp.shape(h), jnp.result_type(h))
    else:
        h_aval = jax.ShapeDtypeStruct(
            (exe.gt.S, exe.gt.n, exe.spec.in_dim), jnp.float32)
    return p_avals, h_aval


def check_executable(exe, *, probe: bool = False,
                     batch_sizes=(1, 2, 3, 5, 7)) -> list[Finding]:
    """Static (always) + dynamic (``probe=True``) analysis of one
    compiled :class:`~repro.runtime.executable.Executable`:

      * RT002 over the parameter pytree (scalar leaves),
      * DT001/2/3 over the traced forward jaxpr (abstract avals — no
        device work, no memory for the activations),
      * with ``probe``: RT003 trace-stability of the jitted forward
        (repeat full-graph calls must not add traces) and of the
        node-batch gather (varying batch sizes within one pad bucket
        must share one trace).
    """
    name = f"Executable[{exe.spec.arch}]"
    out = python_scalar_leaves(exe.params, name=f"{name}.params")

    p_avals, h_aval = _forward_avals(exe)
    closed = jax.make_jaxpr(exe._forward_fn())(p_avals, h_aval)
    out.extend(dtype_findings(closed, name=f"{name}.forward"))

    if probe and exe._h_grouped is not None:
        out.extend(trace_stability(
            exe._jit_forward, [(exe.params, exe._h_grouped)] * 2,
            name=f"{name}.forward"))
        # node-batch path: distinct batch sizes inside one pad bucket
        # must not add gather traces (the PR-7 serving retrace fix)
        n = exe.gt.num_nodes
        for k in batch_sizes:
            exe.forward_nodes(np.arange(min(k, n)))
        gather_traces = cache_size(exe._jit_gather)
        buckets = len({exe._gather_bucket(min(k, n))
                       for k in batch_sizes})
        if gather_traces is not None and gather_traces > buckets:
            out.append(Finding(
                rule="RT003", severity="error", pass_name="retrace",
                message=f"node-batch gather traced {gather_traces}x for "
                        f"{buckets} pad bucket(s) — per-batch-shape "
                        f"recompiles are back",
                location=f"{name}.forward_nodes"))
    return out

"""Comm-contract audit: compiled-HLO collectives vs the partition model.

PR 4 proved the sharded GNN's per-layer all-gathers can be *measured*
from compiled HLO text (:func:`repro.dist.hlo_analysis.analyze_collectives`)
and PAPERS.md's UPC communication-requirements model shows the same
quantity is *derivable* from the partition plan. This pass closes the
loop as a compile-time check for any mesh compile: the measured wire
bytes must match the model, and the model must agree with the
PartitionPlan's independent derivation — drift on either side is a
finding, not a mystery slowdown three benchmarks later.

Rules:

  * **CC001** (error)   — measured all-gather wire bytes disagree with
    the analytic per-layer model beyond ``rtol``: the compiled program
    moves more (or less) data than the plan accounts for.
  * **CC002** (error)   — the PartitionPlan's broadcast model disagrees
    with the analytic model: the two derivations of the same quantity
    have drifted (a modeling bug, not a compiler one).
  * **CC003** (warning) — the program contains collective kinds the
    contract does not model (anything beyond the layer all-gathers and
    the model-axis psum/all-reduce): unaccounted wire traffic.
  * **CC004** (info)    — no collectives at all while none are expected
    (degenerate 1-device mesh): the contract is vacuously satisfied.
"""
from __future__ import annotations

from repro.analyze.report import Finding
from repro.dist.hlo_analysis import CollectiveStats

PASS = "comm"

# collective kinds the sharded-GNN contract accounts for: the per-layer
# feature all-gathers (data axis) and the row-parallel psum reductions
# (model axis; psum lowers to all-reduce)
MODELED_KINDS = frozenset({"all-gather", "all-reduce"})


def check_comm_contract(stats: CollectiveStats, *,
                        expected_allgather_bytes: float,
                        plan_allgather_bytes: float | None = None,
                        rtol: float = 0.02,
                        location: str = "") -> list[Finding]:
    """Findings for one compiled module's collective traffic vs the
    contract (see module docstring). Pure over parsed stats — testable
    without a mesh."""
    out: list[Finding] = []
    measured = stats.wire_bytes.get("all-gather", 0.0)
    expected = float(expected_allgather_bytes)
    tol = rtol * max(expected, 1.0)

    if abs(measured - expected) > tol:
        out.append(Finding(
            rule="CC001", severity="error", pass_name=PASS,
            message=f"measured all-gather wire bytes "
                    f"{measured:,.0f} != modeled {expected:,.0f} "
                    f"(tolerance {tol:,.0f}); the compiled program and "
                    f"the comm model disagree",
            location=location))
    if plan_allgather_bytes is not None and \
            abs(float(plan_allgather_bytes) - expected) > tol:
        out.append(Finding(
            rule="CC002", severity="error", pass_name=PASS,
            message=f"PartitionPlan broadcast model "
                    f"{float(plan_allgather_bytes):,.0f} bytes != analytic "
                    f"per-layer model {expected:,.0f} (tolerance "
                    f"{tol:,.0f}); the two derivations drifted",
            location=location))
    unmodeled = sorted(set(stats.counts) - MODELED_KINDS)
    if unmodeled:
        extra = sum(stats.wire_bytes.get(k, 0.0) for k in unmodeled)
        out.append(Finding(
            rule="CC003", severity="warning", pass_name=PASS,
            message=f"unmodeled collective kinds {unmodeled} put "
                    f"{extra:,.0f} wire bytes on the interconnect outside "
                    f"the contract",
            location=location))
    if not stats.counts and expected == 0.0:
        out.append(Finding(
            rule="CC004", severity="info", pass_name=PASS,
            message="no collectives in the compiled module and none "
                    "expected (1-device mesh): contract vacuously holds",
            location=location))
    return out


def check_comm_stats(cs: dict, *, rtol: float = 0.02,
                     location: str = "") -> list[Finding]:
    """The contract over an already-computed
    :meth:`repro.dist.gnn.ShardedExecutable.comm_stats` dict (the stats
    computation lowers + compiles the module, so callers that already
    hold one should not pay it twice)."""
    stats = CollectiveStats(
        operand_bytes={}, wire_bytes=dict(cs["measured_wire_bytes"]),
        counts=dict(cs["measured_counts"]))
    return check_comm_contract(
        stats,
        expected_allgather_bytes=cs["expected_allgather_wire_bytes"],
        plan_allgather_bytes=sum(
            cs["plan_allgather_bytes_per_layer"].values()),
        rtol=rtol, location=location)


def check_sharded_executable(exe, *, rtol: float = 0.02) -> list[Finding]:
    """Run the contract over a compiled
    :class:`repro.dist.gnn.ShardedExecutable` using its own
    :meth:`comm_stats` accounting."""
    cs = exe.comm_stats()
    return check_comm_stats(
        cs, rtol=rtol,
        location=f"ShardedExecutable[{exe.spec.arch}] "
                 f"data={cs['n_data']} model={cs['n_model']}")

"""Findings + severity-leveled report: the output side of `repro.analyze`.

Every lint pass (retrace, dtype, host-sync, plan, comm) produces
:class:`Finding`s — one rule violation at one location — and the callers
(the ``launch.analyze`` CLI, ``runtime.compile(analyze=...)``, the
``Server`` preflight) aggregate them into a :class:`Report` that knows
how to render itself, serialize to JSON, and answer the only question a
CI gate asks: *did anything at or above the fail threshold fire?*

Severities:

  * ``error``   — the artifact is wrong or will break at runtime
    (illegal plan, contract violation, guaranteed retrace);
  * ``warning`` — a correctness/performance hazard that needs human
    judgement (host sync in a hot path, weak-typed entry argument);
  * ``info``    — context the operator should see (pass skipped,
    suppressed finding count).

Suppression (source-based passes only): a line containing
``analyze: allow(<rule-or-pass>)`` inside any comment suppresses findings
of that rule (or that whole pass) on that line — the same contract as
``noqa``, but namespaced so it can't collide with ruff/flake8 directives.
"""
from __future__ import annotations

import dataclasses

SEVERITIES = ("info", "warning", "error")

# pass names, in report order
PASSES = ("retrace", "dtype", "host-sync", "plan", "comm")


def severity_rank(severity: str) -> int:
    try:
        return SEVERITIES.index(severity)
    except ValueError:
        raise ValueError(f"unknown severity {severity!r}; "
                         f"choose {SEVERITIES}") from None


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one location."""

    rule: str           # e.g. "HS001"
    severity: str       # "info" | "warning" | "error"
    pass_name: str      # "retrace" | "dtype" | "host-sync" | "plan" | "comm"
    message: str
    location: str = ""  # "path:line", plan/layer id, or entry-point name

    def __post_init__(self):
        severity_rank(self.severity)   # validate eagerly

    def render(self) -> str:
        loc = f" [{self.location}]" if self.location else ""
        return f"{self.severity:<7} {self.rule} ({self.pass_name}){loc}: " \
               f"{self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "Finding":
        return cls(**d)


class AnalysisError(RuntimeError):
    """Raised by the ``analyze="error"`` integration hooks when a report
    holds error-severity findings; carries the report for post-mortems."""

    def __init__(self, report: "Report"):
        self.report = report
        super().__init__(report.render())


@dataclasses.dataclass
class Report:
    """Aggregated findings of one analysis run."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    timings_ms: dict[str, float] = dataclasses.field(default_factory=dict)
    # pass -> human reason it did not run (e.g. "1 device: comm pass
    # needs a mesh"); a skip is visible, never silent
    skipped: dict[str, str] = dataclasses.field(default_factory=dict)

    def add(self, *findings: Finding) -> None:
        self.findings.extend(findings)

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def merge(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.timings_ms.update(other.timings_ms)
        self.skipped.update(other.skipped)

    def count(self, severity: str) -> int:
        return sum(1 for f in self.findings if f.severity == severity)

    def at_least(self, severity: str) -> list[Finding]:
        floor = severity_rank(severity)
        return [f for f in self.findings
                if severity_rank(f.severity) >= floor]

    def worst(self) -> str | None:
        if not self.findings:
            return None
        return max((f.severity for f in self.findings), key=severity_rank)

    def failed(self, fail_on: str) -> bool:
        """True when any finding is at/above the threshold. ``fail_on``
        is a severity or ``"never"`` (gate disabled)."""
        if fail_on == "never":
            return False
        return bool(self.at_least(fail_on))

    def render(self) -> str:
        lines = []
        order = {p: i for i, p in enumerate(PASSES)}
        for f in sorted(self.findings,
                        key=lambda f: (-severity_rank(f.severity),
                                       order.get(f.pass_name, len(order)),
                                       f.rule, f.location)):
            lines.append(f.render())
        for pass_name, why in self.skipped.items():
            lines.append(f"skipped {pass_name}: {why}")
        counts = ", ".join(f"{self.count(s)} {s}" for s in
                           reversed(SEVERITIES))
        total_ms = sum(self.timings_ms.values())
        lines.append(f"analyze: {counts} across "
                     f"{len(self.timings_ms)} passes "
                     f"({total_ms:.0f} ms)")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {"findings": [f.to_json() for f in self.findings],
                "timings_ms": {k: round(v, 3)
                               for k, v in self.timings_ms.items()},
                "skipped": dict(self.skipped),
                "counts": {s: self.count(s) for s in SEVERITIES}}

"""qwen2-moe-a2.7b [moe] — 24L d=2048 16H (kv=16) routed d_ff=1408,
vocab=151936, 60 routed experts top-4 + 4 shared experts (4×1408).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, n_shared_experts=4,
                  d_ff_expert=1408, d_ff_shared=1408,
                  router_softmax_topk=True),
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=48,
    vocab_size=256,
    qkv_bias=True,
    moe=MoEConfig(num_experts=6, top_k=2, n_shared_experts=2,
                  d_ff_expert=48, d_ff_shared=48,
                  router_softmax_topk=True),
    param_dtype="float32",
    compute_dtype="float32",
)

"""qwen2-vl-2b [vlm] — 28L d=1536 12H (GQA kv=2) d_ff=8960, vocab=151936,
M-RoPE (sections 16/24/24 over head_dim/2), dynamic resolution.
[arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (B,S,D) plus (3,B,S) M-RoPE position ids
(t/h/w); the backbone transformer is fully implemented.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    input_mode="embeddings",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(2, 3, 3),
    input_mode="embeddings",
    param_dtype="float32",
    compute_dtype="float32",
)

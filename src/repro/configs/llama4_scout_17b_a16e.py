"""llama4-scout-17b-a16e [moe] — 48L d=5120 40H (GQA kv=8) d_ff=8192,
vocab=202048, MoE 16 experts top-1 + 1 shared expert, every layer.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Llama-4 routes with sigmoid scores (router_softmax_topk=False). The
"16E top-1 + shared" structure gives 17B active of ~109B total.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(num_experts=16, top_k=1, n_shared_experts=1,
                  d_ff_expert=8192, d_ff_shared=8192,
                  router_softmax_topk=False),
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama4-scout-17b-a16e-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    moe=MoEConfig(num_experts=4, top_k=1, n_shared_experts=1,
                  d_ff_expert=96, d_ff_shared=96,
                  router_softmax_topk=False),
    rope_theta=500_000.0,
    param_dtype="float32",
    compute_dtype="float32",
)

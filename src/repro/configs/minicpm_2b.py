"""minicpm-2b [dense] — 40L d=2304 36H (MHA kv=36, head_dim 64) d_ff=5760,
vocab=122753, tied embeddings, μP-style scaling (scale_emb=12,
scale_depth=1.4 → residual×1.4/√L, logits×1/(d/dim_model_base=256)) and a
WSD LR schedule (implemented in training/optimizer.py).
[arXiv:2404.06395; hf]
"""
import math

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    emb_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    logit_scale=1.0 / (2304 / 256),
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    tie_embeddings=True,
    emb_scale=12.0,
    residual_scale=1.4 / math.sqrt(2),
    logit_scale=0.25,
    rope_theta=10_000.0,
    param_dtype="float32",
    compute_dtype="float32",
)

"""command-r-plus-104b [dense] — 64L d=12288 96H (GQA kv=8) d_ff=33792,
vocab=256000, no bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]

Note: the real Cohere model uses parallel attention+FFN blocks and
LayerNorm; we use the framework's sequential pre-RMSNorm blocks (recorded
as a deviation in DESIGN.md — it does not change parameter or FLOP counts
materially).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75_000_000.0,
)

SMOKE = ModelConfig(
    name="command-r-plus-104b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab_size=256,
    rope_theta=75_000_000.0,
    param_dtype="float32",
    compute_dtype="float32",
)

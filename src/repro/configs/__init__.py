from repro.configs.registry import (ARCHS, SHAPES, get_config, get_smoke,
                                    shape_applicable)  # noqa: F401

from repro.configs.registry import (ARCHS, SHAPES, get_config, get_smoke,
                                    shape_applicable)

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke", "shape_applicable"]

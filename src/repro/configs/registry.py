"""Architecture registry + assigned input shapes.

Every assigned (arch × shape) cell is defined here; launch/dryrun.py and
the smoke tests iterate this table. ``long_500k`` applies only to
sub-quadratic archs (SSM/hybrid) — full-attention archs skip it, recorded
in DESIGN.md §4 and the roofline table.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "musicgen-large": "repro.configs.musicgen_large",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).SMOKE


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(applicable, reason-if-not)."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch — long_500k skipped (DESIGN.md §4)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]

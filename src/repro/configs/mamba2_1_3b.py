"""mamba2-1.3b [ssm] — 48L d=2048, attention-free (SSD mixer only, no MLP),
vocab=50280, d_state=128, expand=2 → d_inner=4096, 64 heads × head_dim 64.
[arXiv:2405.21060; unverified]

Sub-quadratic: eligible for long_500k (state is O(1) in sequence length).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,            # attention unused
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("mamba2",) * 48,
    mlp_kind="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
    tie_embeddings=True,
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    head_dim=16,
    d_ff=0,
    vocab_size=256,
    block_pattern=("mamba2",) * 2,
    mlp_kind="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                  n_groups=1, chunk_size=8),
    tie_embeddings=True,
    sub_quadratic=True,
    param_dtype="float32",
    compute_dtype="float32",
)

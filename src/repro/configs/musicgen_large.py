"""musicgen-large [audio] — 48L d=2048 32H (MHA kv=32, head_dim 64)
d_ff=8192, vocab=2048, decoder-only over 4 EnCodec codebooks (delay
pattern handled by the data pipeline; the backbone sums 4 codebook
embeddings and emits 4 parallel heads). [arXiv:2306.05284; hf]

The EnCodec audio frontend is a STUB per the assignment; text conditioning
(cross-attention in the original) is out of backbone scope and noted in
DESIGN.md.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    mlp_kind="gelu",
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=64,
    n_codebooks=4,
    mlp_kind="gelu",
    rope_theta=10_000.0,
    param_dtype="float32",
    compute_dtype="float32",
)

"""recurrentgemma-2b [hybrid] — 26L d=2560 10H (MQA kv=1, head_dim 256)
d_ff=7680 (GeGLU), vocab=256000; RG-LRU + local attention (window 2048) in
the Griffin 2:1 pattern (rec, rec, attn). [arXiv:2402.19427; hf]

Sub-quadratic: eligible for long_500k (local attention window bounds the
KV cache at 2048; RG-LRU state is O(1)).
"""
import math

from repro.models.config import ModelConfig, RGLRUConfig

_PATTERN = tuple(("rglru", "rglru", "local_attn")[i % 3] for i in range(26))

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=_PATTERN,
    mlp_kind="geglu",
    local_window=2048,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    emb_scale=math.sqrt(2560),
    tie_embeddings=True,
    rope_theta=10_000.0,
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    block_pattern=("rglru", "rglru", "local_attn"),
    mlp_kind="geglu",
    local_window=16,
    rglru=RGLRUConfig(lru_width=64, conv_width=4),
    emb_scale=8.0,
    tie_embeddings=True,
    rope_theta=10_000.0,
    sub_quadratic=True,
    param_dtype="float32",
    compute_dtype="float32",
)

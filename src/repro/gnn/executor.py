"""Layer execution planner for the model zoo.

For every layer of a :class:`repro.gnn.models.ZooSpec` the planner picks

  * B      — the feature block size (paper §IV-B dimension blocking),
  * n, S   — shard size / grid width that fit the on-chip budget at B,
  * order  — src- vs dst-stationary traversal (Table I),
  * fused  — fused aggregate+extract kernel vs two-stage through HBM,

by *minimizing estimated layer time* under the same Table-I accounting the
platform performance model uses (core/dataflow.py traffic simulation +
core/perf_model.py stage times) — no hardcoded defaults. The chosen plans
feed straight into the runtime forward (B and fused; see
``repro.runtime.compile``) and into graph sharding (``ModelPlan.shard_n``).

Invariant (tested): every plan's working set — source block (n·B), dest
accumulators (n·B) and adjacency block (n·n), double-buffered — fits the
platform's on-chip budget.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib

from repro.core.dataflow import (Dataflow, Order, Traffic, best_order,
                                 simulate_traffic)
from repro.core.perf_model import (CALIBRATION, GNNERATOR, LayerWork,
                                   Platform, dense_stage_time)
from repro.core.sharding import max_shard_nodes_for_budget
from repro.gnn.models import ZooSpec
from repro.utils import cdiv

_F32 = 4
_BLOCK_CANDIDATES = (8, 16, 32, 64, 128, 256)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    layer: int
    d_agg: int              # feature dim live at aggregation time
    B: int                  # chosen feature block (B == d_agg: conventional)
    n: int                  # nodes per shard fitting the budget at B
    S: int                  # shard grid width = ceil(N / n)
    order: Order
    fused: bool
    est_graph_s: float
    est_dense_s: float
    est_layer_s: float
    est_offchip_bytes: float

    def onchip_bytes_used(self, dtype_bytes: int = _F32) -> int:
        """Working set: src block + dst accumulators + adjacency block."""
        return (2 * self.n * self.B + self.n * self.n) * dtype_bytes

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "LayerPlan":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    arch: str
    num_nodes: int
    num_edges: int
    onchip_bytes: int
    platform: str
    layers: tuple[LayerPlan, ...]

    @property
    def shard_n(self) -> int:
        """Single shard size to build GraphTensors with: the tightest
        layer's n (shrinking n only shrinks every layer's working set),
        quantized down to a power of two so same-signature models converge
        on one shard size and share the serving layer's graph-tensor
        cache. Single-shard graphs (n >= N) are left exact."""
        n = min(p.n for p in self.layers)
        if n >= self.num_nodes:
            return n
        return 1 << (n.bit_length() - 1)

    @property
    def total_est_s(self) -> float:
        return sum(p.est_layer_s for p in self.layers)

    def summary(self) -> str:
        rows = [f"{self.arch}: N={self.num_nodes} E={self.num_edges} "
                f"shard_n={self.shard_n} est={self.total_est_s * 1e3:.3f}ms"]
        for p in self.layers:
            rows.append(
                f"  L{p.layer}: D={p.d_agg} B={p.B} S={p.S} n={p.n} "
                f"{p.order} {'fused' if p.fused else 'two-stage'} "
                f"({p.est_layer_s * 1e6:.1f}us, "
                f"{p.est_offchip_bytes / 2**20:.2f}MiB off-chip)")
        return "\n".join(rows)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["layers"] = [p.to_json() for p in self.layers]
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ModelPlan":
        d = dict(d)
        d["layers"] = tuple(LayerPlan.from_json(p) for p in d["layers"])
        return cls(**d)


def _layer_work(spec: ZooSpec, layer: int, num_nodes: int,
                num_edges: int) -> LayerWork:
    """Map a zoo layer onto the perf model's LayerWork accounting."""
    din, dout = spec.layer_dims[layer]
    d_agg = spec.agg_dim(layer)
    if spec.arch == "gcn":
        return LayerWork(num_nodes, num_edges, d_agg, din, dout, False)
    if spec.arch == "sage_mean":
        return LayerWork(num_nodes, num_edges, d_agg, 2 * din, dout, False)
    if spec.arch == "sage_max":   # pool transform runs before aggregation
        return LayerWork(num_nodes, num_edges, d_agg, 2 * din, dout, True,
                         extra_dense_flops=2.0 * num_nodes * din * din)
    if spec.arch == "gin":        # second MLP matmul rides the dense stage
        return LayerWork(num_nodes, num_edges, d_agg, din, dout, False,
                         extra_dense_flops=2.0 * num_nodes * dout * dout)
    if spec.arch == "gat":        # z = hW before aggregation; α-softmax is
        return LayerWork(num_nodes, num_edges, d_agg, din, dout, True,
                         extra_dense_flops=2.0 * num_edges * d_agg)
    raise ValueError(spec.arch)


def _graph_time(p: Platform, work: LayerWork, traffic: Traffic) -> float:
    """Aggregation stage time under the simulated schedule (same accounting
    as perf_model.graph_stage_time, but for an explicit (S, B, order))."""
    flops = 2.0 * work.n_edges * work.d_agg
    t_mem = traffic.offchip_bytes / (p.dram_gbs * 1e9 * p.irregular_eff)
    t_cmp = flops / (p.graph_tflops * 1e12)
    t_edge = traffic.onchip_edge_reads / (CALIBRATION["edge_rate_geps"] * 1e9)
    return max(t_cmp, t_mem, t_edge)


def enumerate_layer_plans(spec: ZooSpec, layer: int, num_nodes: int,
                          num_edges: int, *,
                          platform: Platform = GNNERATOR, max_n: int = 1024,
                          block_candidates: tuple[int, ...] = _BLOCK_CANDIDATES,
                          orders: tuple[Order, ...] | None = None,
                          ) -> list[LayerPlan]:
    """Every (B, n, S, order, fused) candidate for one layer, ranked by
    the Table-I analytic estimate (ascending ``est_layer_s``).

    ``plan_layer`` takes rank 0; the empirical autotuner
    (:mod:`repro.tune`) measures the top-k on the real backend instead of
    trusting the estimate. ``orders`` widens the search beyond the
    analytically best traversal (the tuner passes both)."""
    work = _layer_work(spec, layer, num_nodes, num_edges)
    d = work.d_agg
    budget = int(platform.onchip_graph_mb * 2 ** 20)
    fusable = spec.arch == "gcn"           # linear agg, graph-first, no bias

    cands = sorted({b for b in block_candidates if b < d} | {d})
    out: list[LayerPlan] = []
    for b in cands:
        n = min(max_shard_nodes_for_budget(budget, b, _F32), max_n, num_nodes)
        s = cdiv(num_nodes, n)
        for order in (orders if orders is not None else (best_order(s),)):
            df = Dataflow(S=s, D=d, B=b, order=order)
            traffic = simulate_traffic(df, nodes_per_shard=n,
                                       edges_per_shard=num_edges / (s * s),
                                       dtype_bytes=_F32)
            tg = _graph_time(platform, work, traffic)
            td = dense_stage_time(platform, work, b)
            # fused: fine-grain pipeline at dimension-block granularity, the
            # h_agg intermediate never touches HBM.
            t_fused = max(tg, td) + min(tg, td) / max(df.num_blocks, 1)
            # two-stage: coarse overlap + the intermediate's HBM round trip.
            t_mid = 2.0 * num_nodes * d * _F32 / (platform.dram_gbs * 1e9)
            t_two = max(tg, td) + min(tg, td) / 2 + t_mid
            for fused, t in (((True, t_fused),) if fusable else ()) + \
                            ((False, t_two),):
                out.append(LayerPlan(
                    layer=layer, d_agg=d, B=b, n=n, S=s, order=order,
                    fused=fused, est_graph_s=tg, est_dense_s=td,
                    est_layer_s=t,
                    est_offchip_bytes=traffic.offchip_bytes))
    out.sort(key=lambda p: p.est_layer_s)
    return out


def plan_layer(spec: ZooSpec, layer: int, num_nodes: int, num_edges: int, *,
               platform: Platform = GNNERATOR, max_n: int = 1024,
               block_candidates: tuple[int, ...] = _BLOCK_CANDIDATES,
               ) -> LayerPlan:
    return enumerate_layer_plans(
        spec, layer, num_nodes, num_edges, platform=platform, max_n=max_n,
        block_candidates=block_candidates)[0]


# --------------------------------------------------------------------------
# Model planning, content-hash memoized. Planning is a pure function of
# (spec, graph size, platform, search knobs); the memo key is a sha256 over
# exactly those inputs, so serving restarts and benchmark re-runs skip
# replanning — in-process via _PLAN_CACHE, across processes via JSON files
# in REPRO_PLAN_CACHE (or an explicit cache_dir).
# --------------------------------------------------------------------------

_PLAN_CACHE: dict[str, ModelPlan] = {}
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0, "disk_hits": 0}


def plan_key(spec: ZooSpec, num_nodes: int, num_edges: int, *,
             platform: Platform, max_n: int,
             block_candidates: tuple[int, ...],
             scope: dict | None = None) -> str:
    """Content hash of every input that shapes the plan.

    ``scope`` folds additional key material into the hash. Analytic plans
    are a pure function of (spec, graph size, platform, knobs) and leave
    it ``None``; *measured* plans are only valid for the exact execution
    environment they were timed in, so the autotuner's winner store
    (:mod:`repro.tune.store`) passes the (plan source, kernel backend,
    jax platform, jax version, tuner version, budget, seed) scope — an
    autotuned pallas winner can never be served to a reference-backend
    compile, a different jax install, or a newer tuner."""
    payload = json.dumps({
        "spec": dataclasses.asdict(spec),
        "num_nodes": num_nodes, "num_edges": num_edges,
        "platform": dataclasses.asdict(platform),
        "max_n": max_n, "block_candidates": list(block_candidates),
        **({"scope": scope} if scope else {}),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


def plan_cache_stats() -> dict:
    return dict(_PLAN_CACHE_STATS)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    for k in _PLAN_CACHE_STATS:
        _PLAN_CACHE_STATS[k] = 0


def plan_model(spec: ZooSpec, num_nodes: int, num_edges: int, *,
               platform: Platform = GNNERATOR, max_n: int = 1024,
               block_candidates: tuple[int, ...] = _BLOCK_CANDIDATES,
               cache_dir: str | os.PathLike | None = None,
               ) -> ModelPlan:
    """Plan every layer of a zoo model for one graph (memoized).

    ``cache_dir`` (default: the ``REPRO_PLAN_CACHE`` env var, if set)
    additionally persists plans as JSON so a fresh process reuses them.
    """
    key = plan_key(spec, num_nodes, num_edges, platform=platform,
                   max_n=max_n, block_candidates=block_candidates)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _PLAN_CACHE_STATS["hits"] += 1
        return cached

    if cache_dir is None:
        cache_dir = os.environ.get("REPRO_PLAN_CACHE") or None
    disk = pathlib.Path(cache_dir) / f"{key}.json" if cache_dir else None
    if disk is not None and disk.exists():
        plan = ModelPlan.from_json(json.loads(disk.read_text()))
        _PLAN_CACHE_STATS["disk_hits"] += 1
        _PLAN_CACHE[key] = plan
        return plan

    _PLAN_CACHE_STATS["misses"] += 1
    layers = tuple(
        plan_layer(spec, i, num_nodes, num_edges, platform=platform,
                   max_n=max_n, block_candidates=block_candidates)
        for i in range(len(spec.layer_dims)))
    plan = ModelPlan(arch=spec.arch, num_nodes=num_nodes,
                     num_edges=num_edges,
                     onchip_bytes=int(platform.onchip_graph_mb * 2 ** 20),
                     platform=platform.name, layers=layers)
    _PLAN_CACHE[key] = plan
    if disk is not None:
        disk.parent.mkdir(parents=True, exist_ok=True)
        disk.write_text(json.dumps(plan.to_json()) + "\n")
    return plan

"""GNN model zoo on the GNNerator engines (VersaGNN-style coverage).

Every architecture is assembled from the same two engines the paper builds
in silicon — the Dense Engine (blocked systolic matmul + activation unit)
and the Graph Engine (shard-grid aggregation with dimension blocking) —
composed by the GNNeratorController. Per layer, an executor-provided
:class:`repro.gnn.executor.LayerPlan` picks the feature block size B and
whether the two stages run fused (h_agg never leaves VMEM) or two-stage
through feature memory.

Architectures (all multi-layer, relu between layers, logits at the end):

  gcn        H' = act(Â H W)                       graph-first, fusable
  sage_mean  H' = act(W [mean_N∪u(H); H])          graph-first
  sage_max   z = relu(H W_p + b_p); z̄ = max_N z;
             H' = act(W [z̄; H])                    dense-first (pool)
  gin        H' = MLP((1+ε) H + Σ_N H)             graph-first, ε learnable
  gat        H' = act(‖_heads Σ_u α_vu z_u)        attention-weighted shard
                                                   SpMM (α baked into the
                                                   block grid per head)

The GAT attention weights are computed per shard pair as an (S, S, n, n)
head-block tensor and fed straight to the shard-grid SpMM kernel — the
aggregation stays on the Graph Engine; only the masked softmax runs on the
activation unit (plain jnp here).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import GraphTensors

ARCHS = ("gcn", "sage_mean", "sage_max", "gin", "gat")

# arch -> (edge-weight normalization baked into the shard blocks,
#          add self loops when sharding)
_GRAPH_SIG = {
    "gcn": ("gcn", True),
    "sage_mean": ("mean", True),
    "sage_max": ("sum", True),    # gather path; binary blocks — shares the
                                  # cached GraphTensors with gat
    "gin": ("sum", False),        # (1+ε)·h term replaces the self loop
    "gat": ("sum", True),         # binary mask; α supplies the weights
}


def graph_signature(arch: str) -> tuple[str, bool]:
    """(normalize, add_self_loops) a model needs its GraphTensors built with.

    Serving keys its graph-tensor cache on exactly this signature: two
    models with the same signature share one sharded graph (GNNIE-style
    graph-specific caching).
    """
    return _GRAPH_SIG[arch]


def build_zoo_graph(edges: np.ndarray, num_nodes: int, n: int,
                    arch: str) -> GraphTensors:
    """Deprecated: use ``repro.runtime.compile`` (which builds and caches
    GraphTensors per signature) or ``repro.runtime.forward.build_graph_tensors``."""
    warnings.warn(
        "build_zoo_graph is deprecated; use repro.runtime.compile(...) — "
        "it plans, shards and caches the graph in one call",
        DeprecationWarning, stacklevel=2)
    from repro.runtime.forward import build_graph_tensors
    return build_graph_tensors(edges, num_nodes, n, arch)


@dataclasses.dataclass(frozen=True)
class ZooSpec:
    arch: str
    in_dim: int
    hidden_dim: int
    out_dim: int
    num_layers: int = 2
    heads: int = 2                 # GAT hidden layers (output layer: 1 head)
    eps_init: float = 0.0          # GIN ε initial value (learnable)
    negative_slope: float = 0.2    # GAT LeakyReLU

    def __post_init__(self):
        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}; choose {ARCHS}")
        if self.num_layers < 1:
            raise ValueError("need at least one layer")
        if self.arch == "gat" and self.hidden_dim % self.heads:
            raise ValueError("gat: hidden_dim must divide by heads")

    @property
    def layer_dims(self) -> list[tuple[int, int]]:
        dims = ([self.in_dim] + [self.hidden_dim] * (self.num_layers - 1)
                + [self.out_dim])
        return list(zip(dims[:-1], dims[1:]))

    def agg_dim(self, layer: int) -> int:
        """Feature dim live at aggregation time (what the planner blocks)."""
        din, dout = self.layer_dims[layer]
        if self.arch == "gat":
            # aggregation runs over z = h W (all heads)
            return dout
        return din   # gcn/sage_mean/gin aggregate h; sage_max pools at din


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def init_zoo(key: jax.Array, spec: ZooSpec) -> dict:
    """Param pytree: {"layers": [per-layer dict]}."""
    layers = []
    for i, (din, dout) in enumerate(spec.layer_dims):
        key, k1, k2, k3 = jax.random.split(key, 4)
        if spec.arch == "gcn":
            layer = {"w": _glorot(k1, (din, dout))}
        elif spec.arch == "sage_mean":
            layer = {"w": _glorot(k1, (2 * din, dout))}
        elif spec.arch == "sage_max":
            layer = {"w_pool": _glorot(k1, (din, din)),
                     "b_pool": jnp.zeros((din,), jnp.float32),
                     "w": _glorot(k2, (2 * din, dout))}
        elif spec.arch == "gin":
            layer = {"eps": jnp.float32(spec.eps_init),
                     "w1": _glorot(k1, (din, dout)),
                     "b1": jnp.zeros((dout,), jnp.float32),
                     "w2": _glorot(k2, (dout, dout)),
                     "b2": jnp.zeros((dout,), jnp.float32)}
        elif spec.arch == "gat":
            heads = spec.heads if i < spec.num_layers - 1 else 1
            hd = dout // heads
            if heads * hd != dout:
                raise ValueError(f"gat layer {i}: {dout} !% {heads} heads")
            layer = {"w": _glorot(k1, (din, heads * hd)),
                     "a_src": _glorot(k2, (heads, hd)),
                     "a_dst": _glorot(k3, (heads, hd))}
        layers.append(layer)
    return {"layers": layers}



# --------------------------------------------------------------------------
# Deprecated forward shim (implementation lives in repro.runtime.forward)
# --------------------------------------------------------------------------

def zoo_forward(spec: ZooSpec, params: dict, gt: GraphTensors,
                h: jax.Array, *, plans: Sequence | None = None) -> jax.Array:
    """Deprecated: compile once with ``repro.runtime.compile`` and call
    ``Executable.forward()`` instead of re-chaining plan/graph/forward."""
    warnings.warn(
        "zoo_forward is deprecated; use repro.runtime.compile(...).forward()",
        DeprecationWarning, stacklevel=2)
    from repro.runtime.forward import forward
    return forward(spec, params, gt, h, plans=plans)

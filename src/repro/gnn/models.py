"""GNN model zoo on the GNNerator engines (VersaGNN-style coverage).

Every architecture is assembled from the same two engines the paper builds
in silicon — the Dense Engine (blocked systolic matmul + activation unit)
and the Graph Engine (shard-grid aggregation with dimension blocking) —
composed by the GNNeratorController. Per layer, an executor-provided
:class:`repro.gnn.executor.LayerPlan` picks the feature block size B and
whether the two stages run fused (h_agg never leaves VMEM) or two-stage
through feature memory.

Architectures (all multi-layer, relu between layers, logits at the end):

  gcn        H' = act(Â H W)                       graph-first, fusable
  sage_mean  H' = act(W [mean_N∪u(H); H])          graph-first
  sage_max   z = relu(H W_p + b_p); z̄ = max_N z;
             H' = act(W [z̄; H])                    dense-first (pool)
  gin        H' = MLP((1+ε) H + Σ_N H)             graph-first, ε learnable
  gat        H' = act(‖_heads Σ_u α_vu z_u)        attention-weighted shard
                                                   SpMM (α baked into the
                                                   block grid per head)

The GAT attention weights are computed per shard pair as an (S, S, n, n)
head-block tensor and fed straight to the shard-grid SpMM kernel — the
aggregation stays on the Graph Engine; only the masked softmax runs on the
activation unit (plain jnp here).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import (DenseEngine, GNNeratorController, GraphEngine,
                                GraphTensors)
from repro.core.sharding import shard_graph
from repro.kernels import ops

ARCHS = ("gcn", "sage_mean", "sage_max", "gin", "gat")

# arch -> (edge-weight normalization baked into the shard blocks,
#          add self loops when sharding)
_GRAPH_SIG = {
    "gcn": ("gcn", True),
    "sage_mean": ("mean", True),
    "sage_max": ("sum", True),    # gather path; binary blocks — shares the
                                  # cached GraphTensors with gat
    "gin": ("sum", False),        # (1+ε)·h term replaces the self loop
    "gat": ("sum", True),         # binary mask; α supplies the weights
}


def graph_signature(arch: str) -> tuple[str, bool]:
    """(normalize, add_self_loops) a model needs its GraphTensors built with.

    Serving keys its graph-tensor cache on exactly this signature: two
    models with the same signature share one sharded graph (GNNIE-style
    graph-specific caching).
    """
    return _GRAPH_SIG[arch]


def build_zoo_graph(edges: np.ndarray, num_nodes: int, n: int,
                    arch: str) -> GraphTensors:
    """Shard + normalize a graph for the given zoo architecture."""
    norm, loops = graph_signature(arch)
    sg = shard_graph(edges, num_nodes, n, normalize=norm,
                     add_self_loops=loops)
    return GraphTensors.from_sharded(sg)


@dataclasses.dataclass(frozen=True)
class ZooSpec:
    arch: str
    in_dim: int
    hidden_dim: int
    out_dim: int
    num_layers: int = 2
    heads: int = 2                 # GAT hidden layers (output layer: 1 head)
    eps_init: float = 0.0          # GIN ε initial value (learnable)
    negative_slope: float = 0.2    # GAT LeakyReLU

    def __post_init__(self):
        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}; choose {ARCHS}")
        if self.num_layers < 1:
            raise ValueError("need at least one layer")
        if self.arch == "gat" and self.hidden_dim % self.heads:
            raise ValueError("gat: hidden_dim must divide by heads")

    @property
    def layer_dims(self) -> list[tuple[int, int]]:
        dims = ([self.in_dim] + [self.hidden_dim] * (self.num_layers - 1)
                + [self.out_dim])
        return list(zip(dims[:-1], dims[1:]))

    def agg_dim(self, layer: int) -> int:
        """Feature dim live at aggregation time (what the planner blocks)."""
        din, dout = self.layer_dims[layer]
        if self.arch == "gat":
            # aggregation runs over z = h W (all heads)
            return dout
        return din   # gcn/sage_mean/gin aggregate h; sage_max pools at din


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def init_zoo(key: jax.Array, spec: ZooSpec) -> dict:
    """Param pytree: {"layers": [per-layer dict]}."""
    layers = []
    for i, (din, dout) in enumerate(spec.layer_dims):
        key, k1, k2, k3 = jax.random.split(key, 4)
        if spec.arch == "gcn":
            layer = {"w": _glorot(k1, (din, dout))}
        elif spec.arch == "sage_mean":
            layer = {"w": _glorot(k1, (2 * din, dout))}
        elif spec.arch == "sage_max":
            layer = {"w_pool": _glorot(k1, (din, din)),
                     "b_pool": jnp.zeros((din,), jnp.float32),
                     "w": _glorot(k2, (2 * din, dout))}
        elif spec.arch == "gin":
            layer = {"eps": jnp.float32(spec.eps_init),
                     "w1": _glorot(k1, (din, dout)),
                     "b1": jnp.zeros((dout,), jnp.float32),
                     "w2": _glorot(k2, (dout, dout)),
                     "b2": jnp.zeros((dout,), jnp.float32)}
        elif spec.arch == "gat":
            heads = spec.heads if i < spec.num_layers - 1 else 1
            hd = dout // heads
            if heads * hd != dout:
                raise ValueError(f"gat layer {i}: {dout} !% {heads} heads")
            layer = {"w": _glorot(k1, (din, heads * hd)),
                     "a_src": _glorot(k2, (heads, hd)),
                     "a_dst": _glorot(k3, (heads, hd))}
        layers.append(layer)
    return {"layers": layers}


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _controller(plan) -> GNNeratorController:
    b = plan.B if plan is not None else 128
    fused = plan.fused if plan is not None else True
    return GNNeratorController(dense=DenseEngine(),
                               graph=GraphEngine(block_b=b), fuse=fused)


def _gat_attention_blocks(gt: GraphTensors, z_head: jax.Array,
                          s_src: jax.Array, s_dst: jax.Array,
                          negative_slope: float) -> jax.Array:
    """Per-head attention weights laid out on the shard grid.

    z_head: (S, n, F) head features; s_src/s_dst: (S, n) attention scores.
    Returns α as (S, S, n, n) blocks [dst_shard, src_shard, v, u] ready for
    the shard-grid SpMM kernel.
    """
    mask = gt.blocks != 0                                   # (S, S, n, n)
    logits = s_dst[:, None, :, None] + s_src[None, :, None, :]
    logits = jax.nn.leaky_relu(logits, negative_slope)
    logits = jnp.where(mask, logits, -jnp.inf)
    # masked softmax over ALL of v's in-neighbors: axes (src_shard, u)
    m = jnp.max(logits, axis=(1, 3), keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(mask, jnp.exp(logits - m), 0.0)
    denom = jnp.sum(e, axis=(1, 3), keepdims=True)
    return jnp.where(denom > 0, e / jnp.maximum(denom, 1e-30), 0.0)


def _gat_layer(spec: ZooSpec, layer: dict, gt: GraphTensors, h: jax.Array,
               ctrl: GNNeratorController, *, activation: str) -> jax.Array:
    s, n, din = h.shape
    heads, hd = layer["a_src"].shape
    z = ctrl.dense(h.reshape(s * n, din), layer["w"])       # (S·n, H·hd)
    z = z.reshape(s, n, heads, hd)
    s_src = jnp.einsum("snhf,hf->snh", z.astype(jnp.float32),
                       layer["a_src"].astype(jnp.float32))
    s_dst = jnp.einsum("snhf,hf->snh", z.astype(jnp.float32),
                       layer["a_dst"].astype(jnp.float32))
    outs = []
    for hix in range(heads):   # heads stay sequential: one α grid in VMEM
        alpha = _gat_attention_blocks(gt, z[..., hix, :],
                                      s_src[..., hix], s_dst[..., hix],
                                      spec.negative_slope)
        outs.append(ops.graph_aggregate(alpha, z[..., hix, :],
                                        block_b=ctrl.graph.block_b))
    out = jnp.concatenate(outs, axis=-1)                    # (S, n, H·hd)
    if activation == "relu":
        out = jax.nn.relu(out)
    return out


def zoo_forward(spec: ZooSpec, params: dict, gt: GraphTensors,
                h: jax.Array, *, plans: Sequence | None = None) -> jax.Array:
    """Run the model; h is (S, n, in_dim) shard-grouped (GraphTensors.group).

    ``plans`` is an optional per-layer sequence of LayerPlans from
    repro.gnn.executor; None falls back to the default controller (fused
    where legal, B=128).
    """
    n_layers = len(spec.layer_dims)
    for i, layer in enumerate(params["layers"]):
        plan = plans[i] if plans is not None else None
        ctrl = _controller(plan)
        act = "relu" if i < n_layers - 1 else "none"
        if spec.arch == "gcn":
            h = ctrl.graph_first(gt, h, layer["w"], activation=act)
        elif spec.arch == "sage_mean":
            agg = ctrl.graph.aggregate(gt, h, op="linear")  # mean-normalized
            s, n, d = h.shape
            cat = jnp.concatenate([agg, h], axis=-1).reshape(s * n, 2 * d)
            h = ctrl.dense(cat, layer["w"], activation=act).reshape(s, n, -1)
        elif spec.arch == "sage_max":
            s, n, d = h.shape
            z = ctrl.dense(h.reshape(s * n, d), layer["w_pool"],
                           layer["b_pool"], activation="relu")
            zbar = ctrl.graph.aggregate(gt, z.reshape(s, n, d), op="max")
            cat = jnp.concatenate([zbar, h], axis=-1).reshape(s * n, 2 * d)
            h = ctrl.dense(cat, layer["w"], activation=act).reshape(s, n, -1)
        elif spec.arch == "gin":
            agg = ctrl.graph.aggregate(gt, h, op="linear")  # Σ, no self loop
            x = (1.0 + layer["eps"]) * h + agg
            s, n, d = x.shape
            hid = ctrl.dense(x.reshape(s * n, d), layer["w1"], layer["b1"],
                             activation="relu")
            h = ctrl.dense(hid, layer["w2"], layer["b2"],
                           activation=act).reshape(s, n, -1)
        elif spec.arch == "gat":
            h = _gat_layer(spec, layer, gt, h, ctrl, activation=act)
    return gt.ungroup(h)

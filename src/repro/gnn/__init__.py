"""End-to-end GNN model zoo + layer execution planning (``repro.gnn``).

``models``   — multi-layer GCN / GraphSAGE(mean,max) / GIN / GAT assembled
               from the Dense/Graph engine primitives (core/engines.py) and
               Pallas kernels (kernels/ops.py).
``executor`` — per-layer (S, B, order, fused?) planning via the Table-I
               cost model in core/dataflow.py + core/perf_model.py.
"""
from repro.gnn.executor import LayerPlan, ModelPlan, plan_model  # noqa: F401
from repro.gnn.models import (ARCHS, ZooSpec, build_zoo_graph,  # noqa: F401
                              graph_signature, init_zoo, zoo_forward)

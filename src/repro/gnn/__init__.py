"""GNN model zoo specs + layer execution planning (``repro.gnn``).

``models``   — ZooSpec / init for multi-layer GCN / GraphSAGE(mean,max) /
               GIN / GAT (forward execution lives in ``repro.runtime``;
               ``zoo_forward``/``build_zoo_graph`` remain as deprecation
               shims).
``executor`` — per-layer (S, B, order, fused?) planning via the Table-I
               cost model in core/dataflow.py + core/perf_model.py,
               content-hash memoized with JSON round-tripping.
"""
from repro.gnn.executor import (LayerPlan, ModelPlan, clear_plan_cache,
                                plan_cache_stats, plan_model)
from repro.gnn.models import (ARCHS, ZooSpec, build_zoo_graph,
                              graph_signature, init_zoo, zoo_forward)

__all__ = [
    "LayerPlan", "ModelPlan", "clear_plan_cache", "plan_cache_stats",
    "plan_model", "ARCHS", "ZooSpec", "build_zoo_graph", "graph_signature",
    "init_zoo", "zoo_forward",
]

"""Optimizers and LR schedules (pure JAX, no external deps).

AdamW keeps f32 first/second moments regardless of param dtype (params may
be bf16; the update is computed in f32 and cast back). Schedules include
warmup-cosine and MiniCPM's WSD (warmup-stable-decay).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"         # constant | cosine | wsd
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1          # WSD: fraction of steps in decay phase


def make_schedule(cfg: AdamWConfig) -> Schedule:
    w, t = cfg.warmup_steps, cfg.total_steps

    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / max(w, 1)
        if cfg.schedule == "constant":
            main = jnp.float32(1.0)
        elif cfg.schedule == "cosine":
            frac = jnp.clip((s - w) / max(t - w, 1), 0.0, 1.0)
            main = 0.5 * (1.0 + jnp.cos(math.pi * frac))
        elif cfg.schedule == "wsd":
            # MiniCPM: constant ("stable") phase, then exponential-ish decay
            # over the final decay_frac of training.
            decay_start = t * (1.0 - cfg.decay_frac)
            frac = jnp.clip((s - decay_start) / max(t - decay_start, 1), 0.0, 1.0)
            main = jnp.where(s < decay_start, 1.0, 0.5 ** (frac * 10.0))
        else:
            raise ValueError(cfg.schedule)
        return cfg.lr * jnp.minimum(warm, 1.0) * main

    return sched


def adamw_init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig,
                 schedule: Schedule | None = None):
    """Returns (new_params, new_opt_state, stats)."""
    sched = schedule or make_schedule(cfg)
    step = opt_state["step"] + 1
    lr = sched(step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2 and cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats

"""Gradient compression with error feedback for the DP all-reduce.

int8 per-tensor symmetric quantization: grads are quantized before the
data-parallel reduction (8× wire-traffic reduction on the DP axis) and the
quantization residual is carried to the next step (error feedback — makes
SGD/Adam convergence robust to the compression; Karimireddy et al. 2019).

In the pjit path the quantize/dequantize pair brackets the gradient
computation so XLA's all-reduce runs on the dequantized-but-low-rank-error
values; on a real cluster one would move the all-reduce itself to int8 via
shard_map + ppermute rings. The numerics (what the optimizer sees) are
identical, which is what the convergence tests validate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g32: jax.Array):
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err_feedback=None):
    """Returns (dequantized grads, new error-feedback tree)."""
    def one(g, e):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        q, scale = _quantize(g32)
        deq = _dequantize(q, scale)
        return deq.astype(g.dtype), g32 - deq

    if err_feedback is None:
        err_feedback = jax.tree.map(lambda _: None, grads,
                                    is_leaf=lambda x: x is None)
        flat_g, treedef = jax.tree.flatten(grads)
        outs = [one(g, None) for g in flat_g]
    else:
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(err_feedback)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def wire_bytes_saved(grads) -> float:
    """8× on the DP axis: f32 -> int8 payload (+ one f32 scale/tensor)."""
    total = sum(l.size for l in jax.tree.leaves(grads))
    return total * 4 - (total * 1 + len(jax.tree.leaves(grads)) * 4)

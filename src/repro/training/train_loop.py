"""Train-step construction + the fault-tolerant training loop.

``make_train_step`` builds the jittable (params, opt_state, batch) ->
(params, opt_state, metrics) function used both by real CPU training
(examples/) and by the multi-pod dry-run (launch/dryrun.py lowers exactly
this function against the production mesh).

``TrainLoop`` adds the production concerns: periodic + preemption-signal
checkpointing through checkpoint/manager.py, deterministic resume (data
skip by step), optional int8 gradient compression with error feedback on
the DP axis, and a straggler log hook.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      make_schedule)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    rules=None, *, remat: bool = True,
                    compress_grads: bool = False,
                    barrier_grads: bool = True) -> Callable:
    constrain = rules.constrain if rules is not None else (lambda x, a: x)
    schedule = make_schedule(opt_cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch, constrain=constrain,
                                 remat=remat))(params)
        if barrier_grads:
            # keep the cross-device gradient reductions in the gradients'
            # native dtype (bf16): without the barrier XLA hoists the
            # optimizer's f32 upcast above the all-reduce, doubling DP wire
            # traffic (EXPERIMENTS.md §Perf, command-r E3)
            grads = jax.lax.optimization_barrier(grads)
        if compress_grads:
            from repro.training.compression import compress_decompress
            grads, err = compress_decompress(grads, opt_state.get("ef"))
            opt_state = dict(opt_state, ef=err)
        ef = opt_state.pop("ef", None)
        new_params, new_opt, stats = adamw_update(grads, opt_state, params,
                                                  opt_cfg, schedule)
        if ef is not None:
            new_opt["ef"] = ef
        metrics = {"loss": loss, **stats}
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key,
                     compress_grads: bool = False):
    params = lm.init_params(cfg, key)
    opt_state = adamw_init(params)
    if compress_grads:
        opt_state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, opt_state


def abstract_train_state(cfg: ModelConfig):
    """ShapeDtypeStructs for (params, opt_state) — dry-run stand-ins."""
    params = lm.abstract_params(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt_state = {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return params, opt_state


def train_state_axes(cfg: ModelConfig):
    """Logical-axes trees matching abstract_train_state."""
    from repro.nn.layers import Axes
    axes = lm.param_axes(cfg)
    opt_axes = {
        "m": axes,
        "v": axes,
        "step": Axes(()),
    }
    return axes, opt_axes


@dataclasses.dataclass
class TrainLoop:
    """Fault-tolerant loop: checkpoint/restart, preemption save, metrics.

    ``cfg``/``opt_cfg`` may be None when an explicit ``train_step`` is
    passed to :meth:`run` — the GNN path (runtime/fit.py) builds its own
    jitted step and borrows only the loop mechanics (checkpoint/resume,
    preemption save, straggler log)."""

    cfg: ModelConfig | None
    opt_cfg: AdamWConfig | None
    data_iter: Any                       # step-indexable: data_iter(step)->batch
    ckpt_manager: Any = None             # checkpoint.manager.CheckpointManager
    ckpt_every: int = 100
    log_every: int = 10
    straggler_warn_s: float = 5.0        # log steps slower than this

    def run(self, params, opt_state, num_steps: int, *, train_step=None,
            start_step: int = 0, log: Callable[[str], None] = print):
        step_fn = train_step or jax.jit(
            make_train_step(self.cfg, self.opt_cfg), donate_argnums=(0, 1))

        # resume: restore latest checkpoint if present
        if self.ckpt_manager is not None:
            restored = self.ckpt_manager.restore_latest((params, opt_state))
            if restored is not None:
                (params, opt_state), start_step = restored
                log(f"[resume] restored checkpoint at step {start_step}")

        preempted = {"flag": False}

        def _on_signal(signum, frame):  # graceful preemption save
            preempted["flag"] = True

        old = signal.signal(signal.SIGTERM, _on_signal)
        losses = []
        try:
            t_prev = time.monotonic()
            for step in range(start_step, num_steps):
                batch = self.data_iter(step)   # deterministic by step => resume-safe
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                if step % self.log_every == 0 or step == num_steps - 1:
                    loss = float(metrics["loss"])
                    losses.append((step, loss))
                    dt = time.monotonic() - t_prev
                    log(f"step {step:5d} loss {loss:.4f} "
                        f"lr {float(metrics['lr']):.2e} {dt:.2f}s")
                    if dt > self.straggler_warn_s:
                        log(f"[straggler] step {step} took {dt:.2f}s")
                t_prev = time.monotonic()
                if self.ckpt_manager is not None and (
                        (step + 1) % self.ckpt_every == 0 or preempted["flag"]):
                    self.ckpt_manager.save((params, opt_state), step + 1)
                    if preempted["flag"]:
                        log(f"[preempt] checkpoint saved at step {step + 1}")
                        break
        finally:
            signal.signal(signal.SIGTERM, old)
        return params, opt_state, losses

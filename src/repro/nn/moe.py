"""Mixture-of-Experts with sort-based capacity dispatch.

The GNNerator lesson applied to MoE (DESIGN.md §4): token routing is an
irregular gather/scatter, exactly like the Graph Engine's edge walk. The
TPU-native move is the same one the paper makes for shards — *densify into
MXU-sized blocks*: tokens are argsorted by expert, packed into a static
(E, C, D) capacity buffer with flop-free gathers, pushed through batched
per-expert matmuls, and scatter-combined back. Dispatch therefore costs
ZERO matmul FLOPs (no one-hot dispatch einsums), so compiled HLO FLOPs stay
within capacity_factor of the analytic active-param FLOPs — the
MODEL_FLOPS/HLO_FLOPs roofline ratio stays honest.

Tokens beyond an expert's capacity C = ceil(T·k/E · cf) are dropped
(standard capacity-based MoE); the combine step weights surviving expert
outputs by the (softmaxed) router probabilities. Shared experts (Qwen-MoE)
run densely for every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.layers import Leaf, dense, mlp_apply, mlp_struct


def moe_struct(leaf: Leaf, prefix: str, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    p = {
        "router": leaf(f"{prefix}.router", (d, m.num_experts),
                       ("embed", "experts"), scale=0.02),
        # stacked expert weights: leading experts axis
        "w_gate": leaf(f"{prefix}.w_gate", (m.num_experts, d, m.d_ff_expert),
                       ("experts", "embed", "mlp")),
        "w_up": leaf(f"{prefix}.w_up", (m.num_experts, d, m.d_ff_expert),
                     ("experts", "embed", "mlp")),
        "w_down": leaf(f"{prefix}.w_down", (m.num_experts, m.d_ff_expert, d),
                       ("experts", "mlp", "embed")),
    }
    for i in range(m.n_shared_experts):
        p[f"shared_{i}"] = mlp_struct(leaf, f"{prefix}.shared_{i}", d,
                                      m.d_ff_shared, "swiglu")
    return p


def _capacity(tokens: int, m) -> int:
    c = int(tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    c = max(8, -(-c // 8) * 8)  # pad to a multiple of 8
    # a row of T tokens can route at most T·k entries to one expert — for
    # tiny rows (decode: T=1) the floor of 8 would be pure overcompute
    return min(c, tokens * m.top_k)


def moe_apply(p: dict, x, cfg: ModelConfig, constrain=None):
    """x: (B, S, D) -> (B, S, D).

    Dispatch is BATCHED PER ROW: every sort/gather/scatter carries the
    batch dim as an explicit batching dimension, so under GSPMD a
    batch-sharded residual stream keeps the whole dispatch device-local
    (per-row capacity = per-device capacity, like real EP systems). A
    flattened (B·S) dispatch would force GSPMD to replicate + all-reduce
    full (T, D) f32 buffers every layer — measured 7× FLOPs and ~140
    GB/layer of all-reduce on llama4-scout (EXPERIMENTS.md §Perf).
    """
    constrain = constrain or (lambda t, axes: t)
    m = cfg.moe
    b, s, d = x.shape
    sk = s * m.top_k

    # NOTE (EXPERIMENTS.md §Perf, llama4 E5 — refuted): an explicit
    # all-gather of x at dispatch entry ("act_seq_rep") was hypothesized to
    # beat GSPMD's per-gather resharding, but measured 43% WORSE collective
    # traffic (19.9s -> 28.5s); GSPMD's own placement wins. Left unforced.
    logits = dense(x.astype(jnp.float32), p["router"].astype(jnp.float32))
    top_vals, top_idx = jax.lax.top_k(logits, m.top_k)          # (B, S, k)
    if m.router_softmax_topk:
        weights = jax.nn.softmax(top_vals, axis=-1)
    else:
        weights = jax.nn.sigmoid(top_vals)

    # ---- sort-based dispatch, batched over rows, GATHER-only forward ----
    # (forward scatters would fall back to replicate+all-reduce under
    # GSPMD; a gather-expressed dispatch/combine stays batch-local)
    flat_e = top_idx.reshape(b, sk)                              # (B, S*k)
    sort_idx = jnp.argsort(flat_e, axis=-1)                      # (B, S*k)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    token_of = sort_idx // m.top_k                               # (B, S*k)
    # group boundaries per row
    first_of_e = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(m.num_experts),
                                    side="left"))(sorted_e)      # (B, E)
    counts = jnp.diff(first_of_e, axis=-1,
                      append=jnp.full((b, 1), sk))               # (B, E)
    pos_in_group = jnp.arange(sk)[None, :] - jnp.take_along_axis(
        first_of_e, sorted_e, axis=-1)
    cap = _capacity(s, m)                                        # per-row
    keep = pos_in_group < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_group,
                     m.num_experts * cap - 1)

    # dispatch: buffer slot (e, c) takes the token at sorted position
    # first_of_e[e] + c (if c < counts[e])
    src_q = first_of_e[:, :, None] + jnp.arange(cap)[None, None, :]  # (B,E,cap)
    fill = jnp.arange(cap)[None, None, :] < counts[:, :, None]
    src_q = jnp.minimum(src_q, sk - 1).reshape(b, m.num_experts * cap)
    tok = jnp.take_along_axis(token_of, src_q, axis=-1)          # (B, E*cap)
    buf = jnp.take_along_axis(x, tok[..., None], axis=1)         # (B,E*cap,D)
    buf = buf * fill.reshape(b, m.num_experts * cap, 1).astype(buf.dtype)
    buf = buf.reshape(b, m.num_experts, cap, d)
    buf = constrain(buf, ("act_batch", "experts", "moe_cap", "act_embed"))

    # ---- batched expert FFN (the only matmuls) ----
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    out_e = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    out_e = constrain(out_e, ("act_batch", "experts", "moe_cap", "act_embed"))

    # ---- combine (gather back via the inverse permutation) ----
    out_flat = out_e.reshape(b, m.num_experts * cap, d)
    inv_sort = jnp.argsort(sort_idx, axis=-1)                    # (B, S*k)
    slot_tok = jnp.take_along_axis(slot, inv_sort, axis=-1)      # token order
    keep_tok = jnp.take_along_axis(keep, inv_sort, axis=-1)
    vals = jnp.take_along_axis(out_flat, slot_tok[..., None], axis=1)
    vals = jnp.where(keep_tok[..., None], vals, 0.0)
    y = (vals.reshape(b, s, m.top_k, d)
         * weights[..., None].astype(vals.dtype)).sum(axis=2)

    # ---- shared experts (dense for all tokens) ----
    y = y.astype(x.dtype)
    for i in range(m.n_shared_experts):
        y = y + mlp_apply(p[f"shared_{i}"], x, "swiglu")
    return y

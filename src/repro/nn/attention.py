"""Attention for the LM fleet: GQA, RoPE/M-RoPE, qk-norm, QKV bias, local
windows, chunked online-softmax prefill and ring-buffer decode caches.

Design notes (dry-run fidelity — see DESIGN.md §5):
  * The kv-chunk loop is a *statically unrolled* Python loop with running
    max/denominator (online softmax). XLA's cost_analysis counts while-loop
    bodies once, so lax.scan here would silently undercount attention FLOPs
    by the trip count; unrolling keeps HLO costs exact AND bounds the live
    logit tile to (S × S/nc) — the dimension-blocking discipline of the
    paper applied to the kv axis.
  * Local (sliding window) attention uses a banded path: q is chunked to
    the window size and each q-chunk attends only its two overlapping
    kv-chunks, so prefill FLOPs are O(S·W) not O(S²).
  * Decode keeps a ring buffer of W entries for local layers (pos % W
    indexing) and a full S_max buffer for global layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.layers import Leaf, dense, rms_norm
from repro.nn.rope import apply_mrope, apply_rope

NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def attn_struct(leaf: Leaf, prefix: str, cfg: ModelConfig) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": leaf(f"{prefix}.wq", (d, hq * dh), ("embed", "heads")),
        "wk": leaf(f"{prefix}.wk", (d, hkv * dh), ("embed", "kv_heads")),
        "wv": leaf(f"{prefix}.wv", (d, hkv * dh), ("embed", "kv_heads")),
        "wo": leaf(f"{prefix}.wo", (hq * dh, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = leaf(f"{prefix}.bq", (hq * dh,), ("heads",), init="zeros")
        p["bk"] = leaf(f"{prefix}.bk", (hkv * dh,), ("kv_heads",), init="zeros")
        p["bv"] = leaf(f"{prefix}.bv", (hkv * dh,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = leaf(f"{prefix}.q_norm", (dh,), ("head_dim",), init="zeros")
        p["k_norm"] = leaf(f"{prefix}.k_norm", (dh,), ("head_dim",), init="zeros")
    return p


def _mask_logits(logits, qpos, kpos, window):
    """logits (..., Sq, Sk); qpos (Sq,), kpos (Sk,) absolute positions."""
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    return jnp.where(mask, logits, NEG)


def _sdpa_chunked(q, k, v, qpos, kpos, *, window, n_chunks):
    """Online-softmax over kv chunks. q (B,Hkv,G,Sq,dh); k/v (B,Hkv,Sk,dh)."""
    b, hkv, g, sq, dh = q.shape
    sk = k.shape[2]
    scale = dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    csize = -(-sk // n_chunks)
    m = jnp.full((b, hkv, g, sq), NEG, jnp.float32)
    l = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    for c in range(n_chunks):
        lo, hi = c * csize, min((c + 1) * csize, sk)
        if lo >= hi:
            break
        kc = k[:, :, lo:hi].astype(jnp.float32)
        vc = v[:, :, lo:hi].astype(jnp.float32)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc)
        logits = _mask_logits(logits, qpos, kpos[lo:hi], window)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vc)
        m = m_new
    return acc / jnp.maximum(l, 1e-30)[..., None]


def _sdpa_banded(q, k, v, qpos, kpos, *, window):
    """Local attention: q-chunks of size W attend 2 kv-chunks -> O(S·W)."""
    b, hkv, g, sq, dh = q.shape
    sk = k.shape[2]
    w = window
    if sq <= 2 * w or sq != sk:
        return _sdpa_chunked(q, k, v, qpos, kpos, window=window,
                             n_chunks=max(1, min(8, sk // max(w, 1))))
    scale = dh ** -0.5
    nq = -(-sq // w)
    pad = nq * w - sq
    outs = []
    for c in range(nq):
        lo, hi = c * w, min((c + 1) * w, sq)
        qc = q[:, :, :, lo:hi].astype(jnp.float32) * scale
        klo = max(0, lo - w + 1)
        # kv span covering [klo, hi)
        kc = k[:, :, klo:hi].astype(jnp.float32)
        vc = v[:, :, klo:hi].astype(jnp.float32)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc)
        logits = _mask_logits(logits, qpos[lo:hi], kpos[klo:hi], window)
        p = jax.nn.softmax(logits, axis=-1)
        outs.append(jnp.einsum("bhgqk,bhkd->bhgqd", p, vc))
    out = jnp.concatenate(outs, axis=3)
    del pad
    return out


def _project_qkv(p, x, cfg: ModelConfig):
    b, s, d = x.shape
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope_qk(q, k, positions, cfg: ModelConfig):
    if cfg.rope_kind == "mrope":
        # positions: (3, B, S)
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attn_apply(p: dict, x, cfg: ModelConfig, positions, *, window=None,
               return_kv: bool = False):
    """Full-sequence (train/prefill) attention. x (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    hkv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k, v = _project_qkv(p, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    qg = q.reshape(b, hkv, g, s, cfg.head_dim)
    pos1d = jnp.arange(s)
    if window is not None:
        out = _sdpa_banded(qg, k, v, pos1d, pos1d, window=window)
    else:
        # target ~1k-wide kv chunks: bounds the live logit tile to
        # (Sq × 1024) while keeping the unrolled loop ≤ 32 bodies
        n_chunks = max(1, min(32, s // 1024))
        out = _sdpa_chunked(qg, k, v, pos1d, pos1d, window=None,
                            n_chunks=n_chunks)
    out = out.reshape(b, cfg.n_heads, s, cfg.head_dim)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = dense(out.astype(x.dtype), p["wo"])
    if return_kv:
        return out, (k, v)
    return out


def attn_cache_struct(cfg: ModelConfig, batch: int, max_len: int, window,
                      abstract: bool = False):
    w = min(max_len, window) if window is not None else max_len
    shape = (batch, cfg.n_kv_heads, w, cfg.head_dim)
    if abstract:
        return {"k": jax.ShapeDtypeStruct(shape, cfg.cdtype),
                "v": jax.ShapeDtypeStruct(shape, cfg.cdtype)}
    return {"k": jnp.zeros(shape, cfg.cdtype), "v": jnp.zeros(shape, cfg.cdtype)}


def attn_decode(p: dict, x, cfg: ModelConfig, cache: dict, pos, *, window=None):
    """Single-token decode. x (B,1,D); pos scalar int32; cache k/v
    (B,Hkv,W,dh) where W = window (ring buffer) or max_len."""
    b = x.shape[0]
    dh, hkv, g = cfg.head_dim, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k_new, v_new = _project_qkv(p, x, cfg)
    if cfg.rope_kind == "mrope":
        pos3 = jnp.broadcast_to(pos, (3, b, 1)).astype(jnp.int32)
        q, k_new = _rope_qk(q, k_new, pos3, cfg)
    else:
        pos1 = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        q, k_new = _rope_qk(q, k_new, pos1, cfg)
    w = cache["k"].shape[2]
    slot = pos % w
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, 0, slot, 0))
    # absolute position held by each slot s: pos - ((pos - s) mod w)
    s_idx = jnp.arange(w)
    kpos = pos - ((pos - s_idx) % w)
    valid = kpos >= 0
    if window is not None:
        valid &= kpos > pos - window
    qg = q.reshape(b, hkv, g, 1, dh).astype(jnp.float32) * dh ** -0.5
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG)
    prob = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", prob, v.astype(jnp.float32))
    out = out.reshape(b, cfg.n_heads, 1, dh).transpose(0, 2, 1, 3)
    out = out.reshape(b, 1, cfg.n_heads * dh).astype(x.dtype)
    return dense(out, p["wo"]), {"k": k, "v": v}


def attn_prefill_cache(k, v, max_len: int, window):
    """Build a decode cache from prefill-computed (post-rope) k/v."""
    b, hkv, s, dh = k.shape
    if window is not None and window < max_len:
        w = window
        # last w entries laid out by absolute position mod w
        tail_pos = jnp.arange(s - w, s)
        slots = tail_pos % w
        buf_k = jnp.zeros((b, hkv, w, dh), k.dtype).at[:, :, slots].set(
            k[:, :, s - w:])
        buf_v = jnp.zeros((b, hkv, w, dh), v.dtype).at[:, :, slots].set(
            v[:, :, s - w:])
        return {"k": buf_k, "v": buf_v}
    w = max_len
    pad = w - s
    padk = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    padv = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return {"k": padk, "v": padv}

"""Functional parameter construction + basic layers.

Models are described ONCE by a structure function that receives a *leaf
constructor* ``leaf(name, shape, axes, init=..., scale=...)`` and returns a
param pytree. Instantiating the same structure with different leaf
constructors yields:

  * real parameters        (init_leaf — deterministic per-name RNG fold-in)
  * ShapeDtypeStructs      (abstract_leaf — for .lower() dry-runs, no alloc)
  * logical-axis trees     (axes_leaf — consumed by dist/shardings.py)

so parameters, dry-run stand-ins, and sharding specs can never diverge.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Callable

import jax
import jax.numpy as jnp

Leaf = Callable[..., object]


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical-axis names for one parameter. NOT registered as a pytree
    node, so an axes tree has the same treedef as the param tree and the
    two can be jax.tree.map'ed together."""

    names: tuple

    def __iter__(self):
        return iter(self.names)


def _fold(key, name: str):
    return jax.random.fold_in(key, zlib.crc32(name.encode()) & 0x7FFFFFFF)


def init_leaf(key, dtype) -> Leaf:
    def leaf(name, shape, axes, init="normal", scale=None):
        k = _fold(key, name)
        if init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
            return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
        if init == "embed":
            std = scale if scale is not None else 0.02
            return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "ssm_A":   # A_log: log of Uniform[1, 16]
            u = jax.random.uniform(k, shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(jnp.float32)
        if init == "dt_bias":  # softplus^-1 of Uniform[dt_min, dt_max]
            lo, hi = scale or (0.001, 0.1)
            u = jax.random.uniform(k, shape, jnp.float32, math.log(lo), math.log(hi))
            dt = jnp.exp(u)
            return (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32)
        if init == "lru_lambda":  # softplus^-1 s.t. a in [0.9, 0.999]
            u = jax.random.uniform(k, shape, jnp.float32, 0.9, 0.999)
            log_a = jnp.log(u)   # in (-0.105, -0.001)
            # param c*softplus(L) = -log a  ->  L = softplus^-1(-log a / c)
            x = -log_a / 8.0
            return jnp.log(jnp.expm1(x)).astype(jnp.float32)
        raise ValueError(init)

    return leaf


def abstract_leaf(dtype) -> Leaf:
    f32_inits = {"ssm_A", "dt_bias", "lru_lambda"}

    def leaf(name, shape, axes, init="normal", scale=None):
        dt = jnp.float32 if init in f32_inits else dtype
        return jax.ShapeDtypeStruct(shape, dt)

    return leaf


def axes_leaf() -> Leaf:
    def leaf(name, shape, axes, init="normal", scale=None):
        assert len(axes) == len(shape), (name, shape, axes)
        return Axes(tuple(axes))

    return leaf


# ---------------------------------------------------------------------------
# Layers (pure functions over param dicts)
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with f32 statistics but NO f32 materialization of x: the
    mean-of-squares accumulates in f32 through the dot (MXU-native), and
    only the per-position rsqrt broadcast is f32 — halves the norm's HLO
    bytes vs upcasting the whole tensor (EXPERIMENTS.md §Perf, E8)."""
    dtype = x.dtype
    d = x.shape[-1]
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss / d + eps)[..., None].astype(dtype)
    return (x * inv) * (1.0 + scale.astype(jnp.float32)).astype(dtype)


def dense(x, w, b=None):
    out = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def mlp_struct(leaf: Leaf, prefix: str, d: int, d_ff: int, kind: str) -> dict:
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": leaf(f"{prefix}.w_gate", (d, d_ff), ("embed", "mlp")),
            "w_up": leaf(f"{prefix}.w_up", (d, d_ff), ("embed", "mlp")),
            "w_down": leaf(f"{prefix}.w_down", (d_ff, d), ("mlp", "embed")),
        }
    return {  # plain 2-matmul MLP
        "w_up": leaf(f"{prefix}.w_up", (d, d_ff), ("embed", "mlp")),
        "w_down": leaf(f"{prefix}.w_down", (d_ff, d), ("mlp", "embed")),
    }


def mlp_apply(p: dict, x, kind: str):
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(dense(x, p["w_gate"])) * dense(x, p["w_up"])
        return dense(h, p["w_down"])
    return dense(jax.nn.gelu(dense(x, p["w_up"])), p["w_down"])

"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head_dim/2 frequency bands into sections (temporal,
height, width); each section takes its rotation angle from the matching
component of a 3-row position-id tensor. Text tokens carry identical
(t, h, w) ids, making M-RoPE degenerate to RoPE for them.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """x: (B, H, S, Dh); positions3: (3, B, S) int32; sections sum = Dh/2."""
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    # pick the position row per frequency band
    band = jnp.repeat(
        jnp.arange(len(sections)),
        jnp.array(sections),
        total_repeat_length=dh // 2,
    )                                                    # (Dh/2,) in {0,1,2}
    pos = positions3[band]                               # (Dh/2, B, S)
    ang = pos.transpose(1, 2, 0)[:, None].astype(jnp.float32) * freqs  # (B,1,S,Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)

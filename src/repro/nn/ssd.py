"""Mamba2 (SSD — state-space duality) block, chunked matmul formulation.

The SSD algorithm is itself a blocking dataflow (DESIGN.md §4): the
sequence is split into chunks; *intra*-chunk work becomes dense matmuls
batched over the chunk axis (one einsum, no unrolled loop — HLO FLOPs are
exact), and the *inter*-chunk first-order recurrence over per-chunk states
runs as a log-depth ``associative_scan`` (statically unrolled by XLA, so it
is costed correctly too — a lax.scan here would be undercounted by the
cost model; see DESIGN.md §5).

Shapes: d_in = expand·d_model, H heads of P = head_dim, G state groups,
N = d_state. Conv is a width-4 depthwise causal conv over (x, B, C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.layers import Leaf, dense, rms_norm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nheads, conv_ch


def ssd_struct(leaf: Leaf, prefix: str, cfg: ModelConfig) -> dict:
    s, d_in, nheads, conv_ch = _dims(cfg)
    d = cfg.d_model
    in_dim = 2 * d_in + 2 * s.n_groups * s.d_state + nheads
    return {
        "in_proj": leaf(f"{prefix}.in_proj", (d, in_dim), ("embed", "ssm_in")),
        "conv_w": leaf(f"{prefix}.conv_w", (s.d_conv, conv_ch),
                       ("conv_w", "ssm_conv"), scale=0.5),
        "conv_b": leaf(f"{prefix}.conv_b", (conv_ch,), ("ssm_conv",), init="zeros"),
        "A_log": leaf(f"{prefix}.A_log", (nheads,), ("ssm_heads",), init="ssm_A"),
        "D": leaf(f"{prefix}.D", (nheads,), ("ssm_heads",), init="ones"),
        "dt_bias": leaf(f"{prefix}.dt_bias", (nheads,), ("ssm_heads",),
                        init="dt_bias", scale=(s.dt_min, s.dt_max)),
        "norm": leaf(f"{prefix}.norm", (d_in,), ("ssm_inner",), init="zeros"),
        "out_proj": leaf(f"{prefix}.out_proj", (d_in, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B, L, C), w (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _split_proj(zxbcdt, cfg: ModelConfig):
    s, d_in, nheads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:2 * d_in + 2 * gn]
    dt = zxbcdt[..., 2 * d_in + 2 * gn:]
    return z, xbc, dt


def _split_xbc(xbc, cfg: ModelConfig):
    s, d_in, nheads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    x = xbc[..., :d_in]
    b = xbc[..., d_in:d_in + gn]
    c = xbc[..., d_in + gn:]
    return x, b, c


def _ssd_scan(x, dt, a_log, b, c, cfg: ModelConfig, init_state=None):
    """Chunked SSD. x (B,L,H,P); dt (B,L,H); b/c (B,L,G,N).
    Returns y (B,L,H,P), final_state (B,H,P,N)."""
    s = cfg.ssm
    bt, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    q = min(s.chunk_size, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nc = lp // q
    hpg = h // g  # heads per state group

    A = -jnp.exp(a_log.astype(jnp.float32))                  # (H,) negative
    dt32 = dt.astype(jnp.float32)
    a = dt32 * A[None, None, :]                              # (B,L,H) log-decay
    xc = x.reshape(bt, nc, q, h, p).astype(jnp.float32)
    ac = a.reshape(bt, nc, q, h)
    dtc = dt32.reshape(bt, nc, q, h)
    bc_ = b.reshape(bt, nc, q, g, n).astype(jnp.float32)
    cc = c.reshape(bt, nc, q, g, n).astype(jnp.float32)

    cum_a = jnp.cumsum(ac, axis=2)                           # (B,nc,Q,H)

    # expand state groups to heads (G -> H; heads h map to group h // hpg)
    if g == 1:
        bh = jnp.broadcast_to(bc_[:, :, :, 0:1, :], (bt, nc, q, h, n))
        ch = jnp.broadcast_to(cc[:, :, :, 0:1, :], (bt, nc, q, h, n))
    else:
        bh = jnp.repeat(bc_, hpg, axis=3)
        ch = jnp.repeat(cc, hpg, axis=3)

    # ---- intra-chunk (dense, batched over chunks) ----
    seg = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]  # (B,nc,q,s,H)
    tril = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tril[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bnqhk,bnshk->bnhqs", ch, bh)            # (B,nc,H,Q,Q)
    dt_s = dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]       # (B,nc,H,1,Q=s)
    m = cb * l_mat.transpose(0, 1, 4, 2, 3) * dt_s           # (B,nc,H,q,s)
    y = jnp.einsum("bnhqs,bnshp->bnqhp", m, xc)

    # ---- chunk states ----
    decay_out = jnp.exp(cum_a[:, :, -1:, :] - cum_a)         # (B,nc,Q,H)
    su = jnp.einsum("bnqh,bnqhk,bnqhp->bnhpk", decay_out * dtc, bh, xc)

    # ---- inter-chunk recurrence (associative scan over chunks) ----
    chunk_decay = jnp.exp(cum_a[:, :, -1, :])                # (B,nc,H)
    if init_state is not None:
        # fold the incoming state in as a virtual chunk 0 contribution
        su = su.at[:, 0].add(chunk_decay[:, 0, :, None, None] *
                             init_state.astype(jnp.float32))

    def combine(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, a2[..., None, None] * s1 + s2

    scan_a, scan_s = jax.lax.associative_scan(
        combine, (chunk_decay, su), axis=1)
    # state entering chunk n = scanned result of chunk n-1
    prev = jnp.concatenate(
        [jnp.zeros_like(scan_s[:, :1]), scan_s[:, :-1]], axis=1)

    y_inter = jnp.einsum("bnqh,bnqhk,bnhpk->bnqhp", jnp.exp(cum_a), ch, prev)
    y = (y + y_inter).reshape(bt, lp, h, p)[:, :l]
    final_state = scan_s[:, -1]                              # (B,H,P,N)
    return y, final_state


def ssd_apply(p: dict, x, cfg: ModelConfig):
    """Full-sequence Mamba2 mixer. x (B,S,D) -> (B,S,D)."""
    out, _ = ssd_prefill_cache(p, x, cfg)
    return out


def ssd_cache_struct(cfg: ModelConfig, batch: int, abstract: bool = False):
    s, d_in, nheads, conv_ch = _dims(cfg)
    shapes = {
        "state": (batch, nheads, s.head_dim, s.d_state),
        "conv": (batch, s.d_conv - 1, conv_ch),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(v, jnp.float32) for k, v in shapes.items()}
    return {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}


def ssd_prefill_cache(p: dict, x, cfg: ModelConfig):
    """Run the mixer over the prompt AND return (out, cache) for decode."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    bt, l, d = x.shape
    zxbcdt = dense(x, p["in_proj"])
    z, xbc_pre, dt = _split_proj(zxbcdt, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc_pre, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype)))
    xs, b, c = _split_xbc(xbc, cfg)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    xh = xs.reshape(bt, l, nheads, s.head_dim)
    bg = b.reshape(bt, l, s.n_groups, s.d_state)
    cg = c.reshape(bt, l, s.n_groups, s.d_state)
    y, state = _ssd_scan(xh, dtp, p["A_log"], bg, cg, cfg)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bt, l, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"])
    cache = {
        "state": state,
        "conv": xbc_pre[:, -(s.d_conv - 1):, :].astype(jnp.float32),
    }
    return out, cache


def ssd_decode(p: dict, x, cfg: ModelConfig, cache: dict):
    """Single-token decode. x (B,1,D); cache: state (B,H,P,N), conv
    (B, d_conv-1, conv_ch)."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    bt = x.shape[0]
    zxbcdt = dense(x, p["in_proj"])                          # (B,1,·)
    z, xbc_new, dt = _split_proj(zxbcdt, cfg)
    window = jnp.concatenate([cache["conv"].astype(x.dtype), xbc_new], axis=1)
    conv_out = (window * p["conv_w"].astype(x.dtype)[None]).sum(axis=1, keepdims=True) \
        + p["conv_b"].astype(x.dtype)[None, None]
    xbc = jax.nn.silu(conv_out)                              # (B,1,conv_ch)
    xs, b, c = _split_xbc(xbc, cfg)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])[:, 0]
    xh = xs.reshape(bt, nheads, s.head_dim).astype(jnp.float32)
    bg = b.reshape(bt, s.n_groups, s.d_state).astype(jnp.float32)
    cg = c.reshape(bt, s.n_groups, s.d_state).astype(jnp.float32)
    hpg = nheads // s.n_groups
    bh = jnp.repeat(bg, hpg, axis=1)                         # (B,H,N)
    ch = jnp.repeat(cg, hpg, axis=1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dtp * A[None, :])                           # (B,H)
    state = cache["state"] * da[..., None, None] + \
        jnp.einsum("bh,bhp,bhk->bhpk", dtp, xh, bh)
    y = jnp.einsum("bhpk,bhk->bhp", state, ch) + \
        p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bt, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"])
    new_cache = {
        "state": state,
        "conv": window[:, 1:, :].astype(jnp.float32),
    }
    return out, new_cache

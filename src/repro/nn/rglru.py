"""RecurrentGemma / Griffin recurrent block with the RG-LRU.

Block:  x ->  [linear_x -> causal conv(4) -> RG-LRU]  ⊙  [linear_y -> GeLU]
           -> linear_out

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    log a_t = -c · softplus(Λ) · r_t      (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The diagonal first-order recurrence is evaluated with
``jax.lax.associative_scan`` over time — log-depth, statically unrolled by
XLA, so HLO cost analysis counts it exactly (DESIGN.md §5). Decode is the
closed-form single step on a (B, W) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.layers import Leaf, dense


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_struct(leaf: Leaf, prefix: str, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, _width(cfg)
    cw = cfg.rglru.conv_width
    return {
        "w_x": leaf(f"{prefix}.w_x", (d, w), ("embed", "lru")),
        "w_y": leaf(f"{prefix}.w_y", (d, w), ("embed", "lru")),
        "conv_w": leaf(f"{prefix}.conv_w", (cw, w), ("conv_w", "lru"), scale=0.5),
        "conv_b": leaf(f"{prefix}.conv_b", (w,), ("lru",), init="zeros"),
        "w_a": leaf(f"{prefix}.w_a", (w, w), ("lru", "lru_gate")),
        "b_a": leaf(f"{prefix}.b_a", (w,), ("lru_gate",), init="zeros"),
        "w_i": leaf(f"{prefix}.w_i", (w, w), ("lru", "lru_gate")),
        "b_i": leaf(f"{prefix}.b_i", (w,), ("lru_gate",), init="zeros"),
        "lam": leaf(f"{prefix}.lam", (w,), ("lru",), init="lru_lambda"),
        "w_out": leaf(f"{prefix}.w_out", (w, d), ("lru", "embed")),
    }


def _causal_conv(x, w, b):
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _gates(p, xr, cfg):
    c = cfg.rglru.c_exponent
    r = jax.nn.sigmoid(dense(xr, p["w_a"], p["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(xr, p["w_i"], p["b_i"]).astype(jnp.float32))
    log_a = -c * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xr.astype(jnp.float32))
    return a, gated_in


def rglru_apply(p: dict, x, cfg: ModelConfig, *, return_state: bool = False):
    """x: (B, S, D) -> (B, S, D)."""
    xr = _causal_conv(dense(x, p["w_x"]), p["conv_w"].astype(x.dtype),
                      p["conv_b"].astype(x.dtype))
    a, u = _gates(p, xr, cfg)                       # (B,S,W) f32

    def combine(left, right):
        a1, u1 = left
        a2, u2 = right
        return a1 * a2, a2 * u1 + u2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    y = h.astype(x.dtype) * jax.nn.gelu(dense(x, p["w_y"]))
    out = dense(y, p["w_out"])
    if return_state:
        # final hidden state + conv tail (pre-conv branch input)
        xpre = dense(x, p["w_x"])
        cache = {
            "h": h[:, -1].astype(jnp.float32),
            "conv": xpre[:, -(cfg.rglru.conv_width - 1):, :].astype(jnp.float32),
        }
        return out, cache
    return out


def rglru_cache_struct(cfg: ModelConfig, batch: int, abstract: bool = False):
    w = _width(cfg)
    shapes = {"h": (batch, w), "conv": (batch, cfg.rglru.conv_width - 1, w)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(v, jnp.float32) for k, v in shapes.items()}
    return {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}


def rglru_decode(p: dict, x, cfg: ModelConfig, cache: dict):
    """Single-token decode. x (B,1,D)."""
    xpre = dense(x, p["w_x"])                        # (B,1,W)
    window = jnp.concatenate([cache["conv"].astype(x.dtype), xpre], axis=1)
    xr = (window * p["conv_w"].astype(x.dtype)[None]).sum(axis=1, keepdims=True) \
        + p["conv_b"].astype(x.dtype)[None, None]
    a, u = _gates(p, xr, cfg)                        # (B,1,W)
    h = a[:, 0] * cache["h"] + u[:, 0]               # (B,W)
    y = h[:, None].astype(x.dtype) * jax.nn.gelu(dense(x, p["w_y"]))
    out = dense(y, p["w_out"])
    return out, {"h": h, "conv": window[:, 1:].astype(jnp.float32)}

"""The paper's GNN benchmarks (Table III): GCN, Graphsage, GraphsagePool.

Functional models: ``init_*`` builds a param pytree, ``apply_*`` runs the
forward pass on shard-grouped features via the GNNerator engines. All three
follow the paper's topology — one hidden layer of dimension 16 by default —
but depth/width are configurable (the scaling benchmarks sweep them).

GCN        : H' = relu(Â H W)                       (graph-first, fused)
Graphsage  : z̄ = mean_{N(u)∪u} h ; h' = relu(W [z̄; h])   (graph-first)
GraphsagePool: z = relu(W_pool h) ; z̄ = max z ; h' = relu(W [z̄; h])
                                                     (dense-first!)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import GNNeratorController, GraphTensors
from repro.core.sharding import ShardedGraph, shard_graph


@dataclasses.dataclass(frozen=True)
class GNNSpec:
    kind: str                 # gcn | graphsage | graphsage_pool
    in_dim: int
    hidden_dim: int
    out_dim: int
    num_hidden_layers: int = 1   # paper Table III: 1

    @property
    def layer_dims(self) -> list[tuple[int, int]]:
        dims = [self.in_dim] + [self.hidden_dim] * self.num_hidden_layers + [self.out_dim]
        return list(zip(dims[:-1], dims[1:]))


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def init_gnn(key: jax.Array, spec: GNNSpec) -> dict:
    params: dict = {"layers": []}
    for i, (din, dout) in enumerate(spec.layer_dims):
        key, k1, k2 = jax.random.split(key, 3)
        if spec.kind == "gcn":
            layer = {"w": _glorot(k1, (din, dout))}
        elif spec.kind == "graphsage":
            layer = {"w": _glorot(k1, (2 * din, dout))}
        elif spec.kind == "graphsage_pool":
            layer = {
                "w_pool": _glorot(k1, (din, din)),
                "w": _glorot(k2, (2 * din, dout)),
            }
        else:
            raise ValueError(spec.kind)
        params["layers"].append(layer)
    return params


def build_graph_tensors(sg_edges: np.ndarray, num_nodes: int, n: int,
                        kind: str) -> GraphTensors:
    """Shard + normalize a graph for the given model kind."""
    norm = {"gcn": "gcn", "graphsage": "mean", "graphsage_pool": "max"}[kind]
    sg: ShardedGraph = shard_graph(sg_edges, num_nodes, n, normalize=norm,
                                   add_self_loops=True)
    return GraphTensors.from_sharded(sg)


def make_forward(spec: GNNSpec,
                 controller: GNNeratorController | None = None
                 ) -> Callable[[dict, GraphTensors, jax.Array], jax.Array]:
    """Build apply(params, gt, h_grouped) -> logits (N, out_dim)."""
    ctrl = controller or GNNeratorController()
    n_layers = len(spec.layer_dims)

    def apply(params: dict, gt: GraphTensors, h: jax.Array) -> jax.Array:
        # h: (S, n, in_dim) shard-grouped (see GraphTensors.group)
        for i, layer in enumerate(params["layers"]):
            act = "relu" if i < n_layers - 1 else "none"
            if spec.kind == "gcn":
                h = ctrl.graph_first(gt, h, layer["w"], activation=act)
            elif spec.kind == "graphsage":
                agg = ctrl.graph.aggregate(gt, h, op="linear")  # mean norm
                s, n, d = h.shape
                cat = jnp.concatenate([agg, h], axis=-1).reshape(s * n, 2 * d)
                h = ctrl.dense(cat, layer["w"], activation=act).reshape(s, n, -1)
            elif spec.kind == "graphsage_pool":
                zbar = ctrl.dense_first(gt, h, layer["w_pool"],
                                        activation="relu", agg="max")
                s, n, d = h.shape
                cat = jnp.concatenate([zbar, h], axis=-1).reshape(s * n, 2 * d)
                h = ctrl.dense(cat, layer["w"], activation=act).reshape(s, n, -1)
        return gt.ungroup(h)

    return apply


PAPER_NETWORKS = {  # Table III
    "gcn": dict(kind="gcn", hidden_dim=16, num_hidden_layers=1),
    "graphsage": dict(kind="graphsage", hidden_dim=16, num_hidden_layers=1),
    "graphsage_pool": dict(kind="graphsage_pool", hidden_dim=16,
                           num_hidden_layers=1),
}


def paper_spec(network: str, in_dim: int, num_classes: int) -> GNNSpec:
    cfg = PAPER_NETWORKS[network]
    return GNNSpec(in_dim=in_dim, out_dim=num_classes, **cfg)

"""Analytical platform performance model (paper §V-§VI).

The paper evaluates GNNerator with a cycle-level simulator (PyMTL3 +
SCALE-Sim). Cycle-level RTL simulation is out of scope for a JAX
framework, so we model each platform from its Table-IV resource sheet —
peak compute per engine, on-chip capacity, DRAM bandwidth — and drive it
with the *same dataflow accounting* the framework actually executes
(core/dataflow.py's Table-I shard traffic + dimension-blocked schedules).
This is a first-order roofline/dataflow model: every constant is either
from Table IV or listed in CALIBRATION below with its justification.
The benchmarks compare the model's speedups against the paper's reported
numbers (Fig 3: 8.0× avg over the GPU with blocking, 4.2× without;
Table V: 3.8/3.2/2.3 over HyGCN on GCN) and report the deviation.

Platform semantics:
  * gnnerator      — dual engine, flexible producer/consumer, dimension-
                     blocking (B = dense-engine width by default).
  * gnnerator_noblock — same hardware, conventional dataflow (B = D).
  * hygcn          — dual engine but: no blocking, aggregation must be
                     the producer, and aggregation processes one node at
                     a time (inter-node parallelism unused -> its 1 TFLOP
                     graph engine only streams one node's edges).
  * gpu (2080 Ti)  — single compute pool; irregular aggregation runs at a
                     fraction of DRAM bandwidth (fine-grained gathers).
"""
from __future__ import annotations

import dataclasses

from repro.core.dataflow import Dataflow, simulate_traffic
from repro.core.sharding import max_shard_nodes_for_budget
from repro.graphs.datasets import (DATASETS, TABLE2_DATASETS,
                                   GraphProfile)

# --------------------------------------------------------------------------
# Platforms (paper Table IV) + calibration constants
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    dense_tflops: float          # dense/feature-extraction peak
    graph_tflops: float          # aggregation peak
    onchip_graph_mb: float       # feature scratchpad budget for shards
    dram_gbs: float
    dense_width: int = 64        # systolic width (Fig 4 utilization knee)
    dense_buffer_mb: float = 6.0 # double-buffered output scratchpad (psums)
    irregular_eff: float = 1.0   # DRAM efficiency on irregular access
    blocking: bool = True
    inter_node_parallel: bool = True   # HyGCN: False (one node at a time)


GNNERATOR = Platform("gnnerator", 8.0, 2.0, 24.0, 256.0)
GNNERATOR_NOBLOCK = dataclasses.replace(GNNERATOR, name="gnnerator_noblock",
                                        blocking=False)
HYGCN = Platform("hygcn", 8.0, 1.0, 24.0, 256.0, blocking=False,
                 inter_node_parallel=False)
GPU_2080TI = Platform("gpu", 13.0, 13.0, 5.5, 616.0, dense_width=1,
                      irregular_eff=0.26, blocking=False)

CALIBRATION = {
    # GPU: effective DRAM fraction for fine-grained feature gathers. DGL
    # scatter/gather kernels reach ~15-25% of peak bandwidth on 2080Ti-class
    # parts for <256B random accesses; 0.26 fits the measured averages (grid-searched; see EXPERIMENTS.md).
    "gpu_irregular_eff": 0.26,
    # GPU kernel-launch + framework overhead per layer stage (DGL/PyTorch):
    "gpu_launch_us": 60.0,
    # HyGCN aggregates a single node's full feature at a time (no
    # inter-node parallelism): fine-grained per-node fetches cut the
    # effective aggregation bandwidth AND compute utilization roughly in
    # half vs GNNerator's multi-GPE shard processing (HyGCN paper reports
    # ~50-60% aggregation-engine utilization on these datasets).
    "hygcn_node_serial_eff": 0.4,
    # Shard Compute Unit edge-record throughput (giga-edges/s): the Edge
    # Fetcher walks the shard's edge list once per dimension block — the
    # on-chip overhead the paper concedes for dimension-blocking (§IV-B).
    "edge_rate_geps": 1.0,
}


# --------------------------------------------------------------------------
# Workloads (paper Tables II & III)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerWork:
    """One GNN layer on one dataset."""
    n_nodes: int
    n_edges: int
    d_agg: int        # feature dim at aggregation time
    d_in: int         # dense-engine input dim
    d_out: int        # dense-engine output dim
    dense_first: bool # GraphsagePool: dense is the producer
    extra_dense_flops: float = 0.0   # e.g. pool transform before agg


def network_layers(network: str, prof: GraphProfile,
                   hidden: int = 16, depth: int = 1) -> list[LayerWork]:
    """depth = number of hidden layers (paper Table III: 1); the Fig 5
    scaling study uses deeper stacks with hidden→hidden layers."""
    n, e, f = prof.num_nodes, prof.num_edges, prof.feature_dim
    c = prof.num_classes
    mid = [LayerWork(n, e, hidden, hidden, hidden, False)] * (depth - 1)
    if network == "gcn":
        return [LayerWork(n, e, f, f, hidden, False), *mid,
                LayerWork(n, e, hidden, hidden, c, False)]
    if network == "graphsage":  # concat(agg, h) -> W
        return [LayerWork(n, e, f, 2 * f, hidden, False), *mid,
                LayerWork(n, e, hidden, 2 * hidden, c, False)]
    if network == "graphsage_pool":  # W_pool h -> max-agg -> W [z̄;h]
        return [LayerWork(n, e, f, 2 * f, hidden, True,
                          extra_dense_flops=2.0 * n * f * f), *mid,
                LayerWork(n, e, hidden, 2 * hidden, c, True,
                          extra_dense_flops=2.0 * n * hidden * hidden)]
    raise ValueError(network)


# --------------------------------------------------------------------------
# Stage time models
# --------------------------------------------------------------------------

_F32 = 4


def graph_stage_time(p: Platform, w: LayerWork, block_b: int,
                 sparsity_elim: float = 1.0) -> tuple[float, int]:
    """Aggregation time (s): max(compute, off-chip shard traffic).

    sparsity_elim scales the graph-stage work down (HyGCN's window-sliding
    zero elimination — applies to aggregation only, paper §VI-A).
    """
    d = w.d_agg
    b = min(block_b, d) if p.blocking else d
    n_onchip = max_shard_nodes_for_budget(
        int(p.onchip_graph_mb * 2 ** 20), b, _F32)
    s = max(1, -(-w.n_nodes // n_onchip))
    df = Dataflow(S=s, D=d, B=b)
    tr = simulate_traffic(df, nodes_per_shard=n_onchip,
                          edges_per_shard=w.n_edges / (s * s), dtype_bytes=_F32)
    flops = 2.0 * w.n_edges * d          # multiply-accumulate per edge-dim
    serial = 1.0 if p.inter_node_parallel else \
        CALIBRATION["hygcn_node_serial_eff"]
    t_mem = tr.offchip_bytes / (p.dram_gbs * 1e9 * p.irregular_eff * serial)
    t_cmp = flops / (p.graph_tflops * 1e12 * serial)
    # edge-list re-walk once per dimension block (blocking's on-chip cost)
    t_edge = tr.onchip_edge_reads / (CALIBRATION["edge_rate_geps"] * 1e9 * serial) \
        if p.name != "gpu" else 0.0
    return max(t_cmp, t_mem, t_edge) / sparsity_elim, df.num_blocks


def dense_stage_time(p: Platform, w: LayerWork, block_b: int) -> float:
    flops = 2.0 * w.n_nodes * w.d_in * w.d_out + w.extra_dense_flops
    b = min(block_b, w.d_in) if p.blocking else w.d_in
    util = min(1.0, b / p.dense_width) if p.blocking else 1.0
    # activations in/out once; blocked partial sums reload only for the
    # fraction of a destination tile whose psums exceed the output buffer
    # (paper §IV-B: reloads are "mitigated by the increased reuse").
    act_bytes = w.n_nodes * (w.d_in + w.d_out) * _F32
    n_tile = max_shard_nodes_for_budget(
        int(p.onchip_graph_mb * 2 ** 20), b, _F32)
    tile_out_bytes = min(n_tile, w.n_nodes) * w.d_out * _F32
    spill = max(0.0, 1.0 - p.dense_buffer_mb * 2 ** 20 / max(tile_out_bytes, 1))
    n_blocks = max(w.d_in // max(b, 1), 1)
    psum_extra = (n_blocks - 1) * 2 * w.n_nodes * w.d_out * _F32 * spill
    wt_bytes = w.d_in * w.d_out * _F32
    t_cmp = flops / (p.dense_tflops * 1e12 * util)
    t_mem = (act_bytes + psum_extra + wt_bytes) / (p.dram_gbs * 1e9)
    return max(t_cmp, t_mem)


def layer_time(p: Platform, w: LayerWork, block_b: int = 64,
               sparsity_elim: float = 1.0) -> float:
    t_graph, n_blocks = graph_stage_time(p, w, block_b, sparsity_elim)
    t_dense = dense_stage_time(p, w, block_b)
    if p.name == "gpu":
        # single compute pool, stages serialized + launch overhead
        return t_graph + t_dense + 2 * CALIBRATION["gpu_launch_us"] * 1e-6
    if w.dense_first and not p.blocking and p.name == "hygcn":
        # HyGCN cannot run the Dense Engine as producer: the pool transform
        # serializes through DRAM before aggregation can start.
        return t_graph + t_dense
    overlap_grain = n_blocks if p.blocking else 2
    return max(t_graph, t_dense) + min(t_graph, t_dense) / max(overlap_grain, 1)


def model_time(p: Platform, network: str, dataset: str, *,
               block_b: int = 64, hidden: int = 16, depth: int = 1,
               sparsity_elim: float = 1.0) -> float:
    prof = DATASETS[dataset]
    return sum(layer_time(p, w, block_b, sparsity_elim)
               for w in network_layers(network, prof, hidden, depth))


def speedup_table(block_b: int = 64) -> dict:
    """Fig 3 + Table V reproduction: speedups vs the GPU baseline."""
    out: dict = {}
    for net in ("gcn", "graphsage", "graphsage_pool"):
        for ds in TABLE2_DATASETS:
            t_gpu = model_time(GPU_2080TI, net, ds)
            row = {
                "gpu_ms": t_gpu * 1e3,
                "gnnerator": t_gpu / model_time(GNNERATOR, net, ds,
                                                block_b=block_b),
                "gnnerator_noblock": t_gpu / model_time(GNNERATOR_NOBLOCK,
                                                        net, ds),
                "hygcn": t_gpu / model_time(HYGCN, net, ds),
            }
            out[f"{net}/{ds}"] = row
    return out

"""2-D graph sharding (paper §II-B, Fig. 1).

A graph's edge list is divided into an S×S grid of shards: shard (i, j)
holds every edge whose destination falls in node-range i and whose source
falls in node-range j, with at most ``n`` source / ``n`` destination nodes
per shard (so ≤ n² edges per shard). Shards can then be traversed in a
source-stationary (row-major) or destination-stationary (column-major)
manner — see core/dataflow.py.

TPU adaptation: each occupied shard's sub-adjacency is *densified* into an
(n, n) block so the aggregation becomes an MXU matmul (kernels/shard_spmm).
The edge list per shard is also kept (padded CSR/COO) for the gather-based
aggregator (kernels/seg_gather) used for non-linear reductions (max-pool).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

from repro.utils import cdiv

Aggregator = Literal["sum", "mean", "gcn", "max"]


@dataclasses.dataclass
class ShardedGraph:
    """A graph partitioned into an S×S shard grid with node-range size n."""

    num_nodes: int          # true number of nodes N (before padding)
    n: int                  # nodes per shard range (paper's n)
    S: int                  # grid width/height: ceil(N / n)
    # Dense per-shard adjacency blocks, shape (S, S, n, n), A[i, j, v, u] is
    # the edge weight of (src=j*n+u -> dst=i*n+v). Zero where no edge.
    blocks: np.ndarray
    # Padded per-shard COO edge lists for the gather path.
    # edge_src/edge_dst: (S, S, E_max) int32, local indices in [0, n);
    # edge_valid: (S, S, E_max) bool.
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_valid: np.ndarray
    num_edges: int          # true number of edges (incl. self loops if added)
    degrees: np.ndarray     # (N_padded,) in-degree used for normalization

    @property
    def n_padded(self) -> int:
        return self.S * self.n

    @property
    def occupancy(self) -> np.ndarray:
        """(S, S) edge count per shard."""
        return self.edge_valid.sum(axis=-1)

    @property
    def density(self) -> float:
        """Fraction of occupied-shard block entries that are real edges."""
        occ = self.occupancy
        nz = (occ > 0).sum()
        if nz == 0:
            return 0.0
        return float(occ.sum()) / (nz * self.n * self.n)


def shard_graph(
    edges: np.ndarray,
    num_nodes: int,
    n: int,
    *,
    add_self_loops: bool = True,
    normalize: Aggregator = "gcn",
) -> ShardedGraph:
    """Shard an edge list into the 2-D grid of the paper.

    Args:
      edges: (E, 2) int array of (src, dst) pairs.
      num_nodes: N.
      n: max source/destination nodes per shard (paper's tunable n).
      add_self_loops: include u->u edges (GCN/Graphsage aggregate over
        N(u) ∪ {u}).
      normalize: edge-weight normalization baked into the dense blocks:
        'sum'  -> 1.0
        'mean' -> 1/deg(dst)  (Graphsage mean aggregator)
        'gcn'  -> 1/sqrt(deg(src) deg(dst))  (Kipf & Welling)
        'max'  -> 1.0 (blocks unused; max uses the gather path)
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.ndim != 2 or edges.shape[1] != 2:
        raise ValueError(f"edges must be (E, 2), got {edges.shape}")
    if add_self_loops:
        loops = np.stack([np.arange(num_nodes)] * 2, axis=1)
        edges = np.concatenate([edges, loops], axis=0)
    src, dst = edges[:, 0], edges[:, 1]

    S = cdiv(num_nodes, n)
    n_padded = S * n

    deg = np.zeros(n_padded, dtype=np.float64)
    np.add.at(deg, dst, 1.0)
    deg_src = np.zeros(n_padded, dtype=np.float64)
    np.add.at(deg_src, src, 1.0)

    if normalize == "gcn":
        w = 1.0 / np.sqrt(np.maximum(deg_src[src], 1.0) * np.maximum(deg[dst], 1.0))
    elif normalize == "mean":
        w = 1.0 / np.maximum(deg[dst], 1.0)
    else:  # sum / max
        w = np.ones_like(src, dtype=np.float64)

    # Shard coordinates and local indices.
    si, sj = dst // n, src // n            # shard row (dst), shard col (src)
    lv, lu = dst % n, src % n              # local dst, local src

    blocks = np.zeros((S, S, n, n), dtype=np.float32)
    # accumulate duplicates (multigraph-safe)
    np.add.at(blocks, (si, sj, lv, lu), w.astype(np.float32))

    # COO per shard, padded to the max occupancy (>=1 to keep shapes sane).
    counts = np.zeros((S, S), dtype=np.int64)
    np.add.at(counts, (si, sj), 1)
    e_max = max(int(counts.max()), 1)
    edge_src = np.zeros((S, S, e_max), dtype=np.int32)
    edge_dst = np.zeros((S, S, e_max), dtype=np.int32)
    edge_valid = np.zeros((S, S, e_max), dtype=bool)
    order = np.lexsort((sj, si))
    flat = si[order] * S + sj[order]
    # position of each edge within its shard
    pos = np.zeros_like(flat)
    if len(flat):
        new_shard = np.concatenate([[True], flat[1:] != flat[:-1]])
        idx_in_run = np.arange(len(flat))
        run_start = np.maximum.accumulate(np.where(new_shard, idx_in_run, 0))
        pos = idx_in_run - run_start
    edge_src[si[order], sj[order], pos] = lu[order]
    edge_dst[si[order], sj[order], pos] = lv[order]
    edge_valid[si[order], sj[order], pos] = True

    return ShardedGraph(
        num_nodes=num_nodes,
        n=n,
        S=S,
        blocks=blocks,
        edge_src=edge_src,
        edge_dst=edge_dst,
        edge_valid=edge_valid,
        num_edges=int(edges.shape[0]),
        degrees=deg,
    )


def max_shard_nodes_for_budget(
    onchip_bytes: int, feature_block: int, dtype_bytes: int = 4, dual_buffer: bool = True
) -> int:
    """How many nodes n fit on-chip given a feature block of B dims.

    Paper §IV-B: dimension-blocking keeps only B of D dims resident, so
    n grows by ~D/B, shrinking the shard-grid S and the Table-I costs.
    On TPU the 'on-chip' budget is the VMEM window for the kernel.
    We need source features (n×B), destination accumulators (n×B) and the
    adjacency block (n×n); double-buffering halves the budget.
    """
    budget = onchip_bytes // (2 if dual_buffer else 1)
    # n*B*dtype*2 + n*n*dtype <= budget  -> solve quadratic in n
    a = dtype_bytes
    b = 2 * feature_block * dtype_bytes
    disc = b * b + 4 * a * budget
    n = int((-b + disc ** 0.5) / (2 * a))
    return max(n, 1)

"""GNN dataflows (paper §IV, Algorithm 1 + Table I).

The conventional dataflow walks the S×S shard grid with the *entire*
feature vector (B = D) resident per node. The paper's feature
dimension-blocking dataflow adds an outer loop over D/B feature blocks so
only an (n × B) slice of features is on-chip at a time, allowing larger
shards (bigger n, smaller S) for a fixed on-chip budget.

This module provides:
  * schedule generation (loop-nest iteration order, src-/dst-stationary,
    serpentine S-pattern),
  * the analytical Table-I read/write cost model,
  * a traffic simulator that walks a schedule and counts actual off-chip
    feature transfers + on-chip edge re-reads (used to validate Table I and
    to drive the platform performance model in core/perf_model.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Literal

import numpy as np

from repro.utils import cdiv

Order = Literal["src_stationary", "dst_stationary"]


@dataclasses.dataclass(frozen=True)
class Dataflow:
    """A dimension-blocked shard-grid schedule (Algorithm 1)."""

    S: int                  # shard grid width/height
    D: int                  # feature dimension
    B: int                  # feature block size (B == D -> conventional)
    order: Order = "dst_stationary"
    serpentine: bool = True  # S-pattern: reverse inner loop on odd outer steps

    @property
    def num_blocks(self) -> int:
        return cdiv(self.D, self.B)

    def steps(self) -> Iterator[tuple[int, int, int]]:
        """Yield (dim_block, dst_shard, src_shard) in execution order.

        dst_stationary: for each block, for each dst, sweep src (dst
        features stay resident until fully aggregated).
        src_stationary: for each block, for each src, sweep dst.
        """
        for blk in range(self.num_blocks):
            for outer in range(self.S):
                inner_range = range(self.S)
                if self.serpentine and outer % 2 == 1:
                    inner_range = reversed(inner_range)  # type: ignore[assignment]
                for inner in inner_range:
                    if self.order == "dst_stationary":
                        yield blk, outer, inner
                    else:
                        yield blk, inner, outer


# --------------------------------------------------------------------------
# Table I: analytical read/write costs (in units of shard-feature transfers,
# i.e. one unit = one shard's worth of node features for the resident block).
# --------------------------------------------------------------------------

def table1_costs(S: int, I: float = 1.0) -> dict[str, dict[str, float]]:
    """Paper Table I, verbatim.

    I is the maximum number of input features required on-chip at one time
    (the paper's I); with an S-pattern traversal, a stationary set is
    carried across the grid and the moving set is (re)loaded per shard.
    """
    return {
        "src_stationary": {
            "read": S * I + (S - 1) * S - S + 1,
            "write": S * S - S + 1,
        },
        "dst_stationary": {
            "read": (S * S - S + 1) * I,
            "write": float(S),
        },
    }


def best_order(S: int, I: float = 1.0, read_cost: float = 1.0, write_cost: float = 1.0) -> Order:
    """Pick the cheaper traversal order per Table I (equal rd/wr cost by default)."""
    c = table1_costs(S, I)
    tot = {k: v["read"] * read_cost + v["write"] * write_cost for k, v in c.items()}
    return min(tot, key=tot.get)  # type: ignore[return-value]


# --------------------------------------------------------------------------
# Traffic simulation: walk the schedule, count actual transfers.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Traffic:
    """Off-chip feature bytes + on-chip edge walks for one layer's aggregation."""

    offchip_read_bytes: float
    offchip_write_bytes: float
    onchip_edge_reads: float     # edge-record reads (edge list walked D/B times)
    steps: int

    @property
    def offchip_bytes(self) -> float:
        return self.offchip_read_bytes + self.offchip_write_bytes


def simulate_traffic(
    df: Dataflow,
    *,
    nodes_per_shard: int,
    edges_per_shard: np.ndarray | float,
    dtype_bytes: int = 4,
    edge_bytes: int = 8,
    skip_empty: bool = True,
) -> Traffic:
    """Count off-chip transfers for a schedule.

    Accounting (matches Table I exactly — validated in benchmarks):
      * SOURCE features are inputs: read from DRAM whenever a source block
        becomes resident (stationary: once per residency; moving: on every
        entry, with the serpentine S-pattern saving one reload per turn).
      * DESTINATION accumulators start at zero ON-CHIP (no first-touch
        read); they are written back on every eviction and RE-read when a
        previously evicted destination becomes resident again (partial-sum
        reload in the src-stationary order).
      * every visited shard's edge list is walked once per dimension block.
    """
    S, B = df.S, df.B
    blk_feat_bytes = nodes_per_shard * B * dtype_bytes

    if np.isscalar(edges_per_shard):
        occ = np.full((S, S), float(edges_per_shard))
    else:
        occ = np.asarray(edges_per_shard, dtype=np.float64)

    reads = 0.0
    writes = 0.0
    edge_reads = 0.0
    steps = 0

    dst_stationary = df.order == "dst_stationary"
    resident_outer = -1
    resident_inner = -1
    touched_dst: set[tuple[int, int]] = set()
    for blk, dst, src in df.steps():
        outer, inner = (dst, src) if dst_stationary else (src, dst)
        if skip_empty and occ[dst, src] == 0:
            continue
        steps += 1
        if outer != resident_outer:
            if dst_stationary:
                # retire old dst accumulator; new one initializes on-chip
                if resident_outer >= 0:
                    writes += blk_feat_bytes
            else:
                # src stationary: read the new stationary source set
                reads += blk_feat_bytes
            resident_outer = outer
            # NOTE: the moving set is NOT evicted on an outer change — the
            # serpentine S-pattern begins the next sweep at the same inner
            # index, which is exactly the reload Table I's "-S+1" saves.
        if inner != resident_inner:
            if dst_stationary:
                reads += blk_feat_bytes          # moving source set: input
            else:
                # moving destination: write back the one we evict, reload
                # partials if this dst was visited before (else init 0)
                if resident_inner >= 0:
                    writes += blk_feat_bytes
                if (blk, inner) in touched_dst:
                    reads += blk_feat_bytes
                touched_dst.add((blk, inner))
            resident_inner = inner
        edge_reads += occ[dst, src]
    # retire the final destination set
    if resident_outer >= 0 or resident_inner >= 0:
        writes += blk_feat_bytes
    return Traffic(
        offchip_read_bytes=reads,
        offchip_write_bytes=writes,
        onchip_edge_reads=edge_reads,
        steps=steps,
    )


def blocked_vs_conventional(
    *,
    num_nodes: int,
    D: int,
    B: int,
    onchip_bytes: int,
    dtype_bytes: int = 4,
) -> dict[str, float]:
    """Headline comparison (paper §IV-B): for a fixed on-chip budget, the
    blocked dataflow fits n_blocked = budget/(B) nodes vs n_conv =
    budget/(D) nodes, so S shrinks by ~D/B and off-chip traffic drops.

    Returns the shard counts and Table-I read totals for both dataflows.
    """
    from repro.core.sharding import max_shard_nodes_for_budget

    n_conv = max_shard_nodes_for_budget(onchip_bytes, D, dtype_bytes)
    n_blk = max_shard_nodes_for_budget(onchip_bytes, B, dtype_bytes)
    S_conv = cdiv(num_nodes, n_conv)
    S_blk = cdiv(num_nodes, n_blk)
    costs_conv = table1_costs(S_conv)["dst_stationary"]
    costs_blk = table1_costs(S_blk)["dst_stationary"]
    # per-block cost × number of blocks, in node-feature-block units that we
    # convert to bytes for a fair comparison
    conv_bytes = (costs_conv["read"] + costs_conv["write"]) * n_conv * D * dtype_bytes
    # the last (partial) feature block still costs a full grid sweep, so the
    # block count is ceil(D/B) — flooring undercounts traffic when B ∤ D
    blk_bytes = (
        (costs_blk["read"] + costs_blk["write"]) * n_blk * B * dtype_bytes * cdiv(D, max(B, 1))
    )
    return {
        "n_conventional": n_conv,
        "n_blocked": n_blk,
        "S_conventional": S_conv,
        "S_blocked": S_blk,
        "offchip_bytes_conventional": conv_bytes,
        "offchip_bytes_blocked": blk_bytes,
        "traffic_ratio": conv_bytes / max(blk_bytes, 1.0),
    }

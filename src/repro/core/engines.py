"""Dense Engine / Graph Engine abstractions (paper §III).

On the ASIC these are two physical compute engines coordinated by the
GNNerator Controller (either may be producer or consumer). In the JAX/TPU
port they are thin, configurable wrappers over the Pallas kernels; the
Controller's role — deciding the producer/consumer order and whether the
two stages can be fine-grain pipelined — becomes a kernel-selection
decision: graph-first layers with linear aggregation use the *fused*
kernel (h_agg never leaves VMEM), everything else composes the two engine
kernels through HBM exactly like the ASIC's feature memory.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.sharding import ShardedGraph
from repro.kernels.registry import KernelBackend, resolve


@dataclasses.dataclass(frozen=True)
class GraphTensors:
    """Device-ready arrays for one sharded graph + one normalization."""

    blocks: jax.Array      # (S, S, n, n) densified adjacency (normalized)
    edge_src: jax.Array    # (S, S, E) int32
    edge_dst: jax.Array    # (S, S, E) int32
    edge_valid: jax.Array  # (S, S, E) bool
    num_nodes: int
    n: int
    S: int

    @classmethod
    def from_sharded(cls, sg: ShardedGraph) -> "GraphTensors":
        return cls(
            blocks=jnp.asarray(sg.blocks),
            edge_src=jnp.asarray(sg.edge_src),
            edge_dst=jnp.asarray(sg.edge_dst),
            edge_valid=jnp.asarray(sg.edge_valid),
            num_nodes=sg.num_nodes,
            n=sg.n,
            S=sg.S,
        )

    @property
    def occupancy(self):
        """(S, S) edge count per shard (numpy) — lets graphs/partition.py
        plan over cached GraphTensors exactly like a ShardedGraph."""
        import numpy as np
        return np.asarray(self.edge_valid.sum(axis=-1))

    def group(self, h: jax.Array) -> jax.Array:
        """(N, D) node features -> (S, n, D) shard-grouped (zero padded)."""
        d = h.shape[-1]
        pad = self.S * self.n - h.shape[0]
        h = jnp.pad(h, ((0, pad), (0, 0)))
        return h.reshape(self.S, self.n, d)

    def ungroup(self, h: jax.Array) -> jax.Array:
        """(S, n, D) -> (N, D)."""
        d = h.shape[-1]
        return h.reshape(self.S * self.n, d)[: self.num_nodes]


@dataclasses.dataclass(frozen=True)
class DenseEngine:
    """Feature extraction: blocked systolic matmul + activation unit.

    ``backend`` pins a :class:`~repro.kernels.registry.KernelBackend`;
    None resolves per call from the registry (env-var selectable)."""

    bm: int = 128
    bn: int = 128
    bk: int = 128
    backend: KernelBackend | None = None

    def __call__(self, x, w, b=None, *, activation: str = "none"):
        be = self.backend or resolve("dense_matmul")
        return be.dense_matmul(x, w, b, activation=activation,
                               bm=self.bm, bn=self.bn, bk=self.bk)


@dataclasses.dataclass(frozen=True)
class GraphEngine:
    """Aggregation over the shard grid with dimension-blocking."""

    block_b: int = 128   # the paper's B (feature block size)
    backend: KernelBackend | None = None

    def aggregate(self, gt: GraphTensors, h: jax.Array, *,
                  op: Literal["linear", "max", "sum"] = "linear") -> jax.Array:
        """h: (S, n, D) shard-grouped. Linear = weights baked into blocks
        (sum/mean/gcn); max/sum go through the edge-list gather kernel."""
        if op == "linear":
            return self.spmm(gt.blocks, h)
        be = self.backend or resolve("gather_aggregate")
        return be.gather_aggregate(gt.edge_src, gt.edge_dst, gt.edge_valid,
                                   h, op=op, block_b=self.block_b)

    def spmm(self, blocks: jax.Array, h: jax.Array) -> jax.Array:
        """Shard-grid SpMM on explicit (S, S, n, n) blocks — used directly
        by attention-weighted aggregation (GAT), where the weights are not
        baked into the cached GraphTensors."""
        be = self.backend or resolve("graph_aggregate")
        return be.graph_aggregate(blocks, h, block_b=self.block_b)


@dataclasses.dataclass(frozen=True)
class GNNeratorController:
    """Composes the engines per layer topology (paper §III-C).

    graph-first + linear aggregation -> fused kernel (fine-grain pipeline);
    otherwise the stages run back-to-back through feature memory.
    """

    dense: DenseEngine = DenseEngine()
    graph: GraphEngine = GraphEngine()
    fuse: bool = True

    def graph_first(self, gt: GraphTensors, h: jax.Array, w: jax.Array,
                    b=None, *, activation: str = "none") -> jax.Array:
        """act((A · H) · W) — GCN-style layer body on grouped features."""
        if self.fuse and b is None:
            be = self.graph.backend or resolve("fused_aggregate_extract")
            return be.fused_aggregate_extract(
                gt.blocks, h, w, activation=activation,
                block_b=self.graph.block_b)
        agg = self.graph.aggregate(gt, h, op="linear")
        s, n, d = agg.shape
        out = self.dense(agg.reshape(s * n, d), w, b, activation=activation)
        return out.reshape(s, n, -1)

    def dense_first(self, gt: GraphTensors, h: jax.Array, w_pool: jax.Array,
                    b_pool=None, *, activation: str = "none",
                    agg: Literal["max", "sum"] = "max") -> jax.Array:
        """agg(act(H · W_pool)) — GraphsagePool-style: Dense Engine is the
        producer, Graph Engine the consumer."""
        s, n, d = h.shape
        z = self.dense(h.reshape(s * n, d), w_pool, b_pool,
                       activation=activation)
        z = z.reshape(s, n, -1)
        return self.graph.aggregate(gt, z, op=agg)

"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests and benches must keep seeing the
single real CPU device; only launch/dryrun.py forces 512 host devices.

Production target: TPU v5e pods. Single pod = 16×16 = 256 chips
(data, model); multi-pod = 2×16×16 = 512 chips (pod, data, model) where
the leading "pod" axis crosses DCN. Designed so the same logical sharding
rules scale to N pods by growing the leading axis (elastic scaling: see
dist/shardings.py — batch shards over ("pod","data") and re-lowers for any
pod count without code changes).

``make_mesh_for`` is the elastic variant the GNN runtime uses:
``runtime.compile(spec, graph, mesh=make_mesh_for(jax.device_count()))``
returns a sharded Executable (see dist/gnn.py). Mesh construction goes
through dist/compat.py so both jax 0.4.x and >= 0.5 work.
"""
from __future__ import annotations

from repro.dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(devices: int, *, model_parallel: int = 16):
    """Elastic variant: build a (data, model) mesh for whatever device
    count the scheduler hands us (node failures / scale-up)."""
    assert devices % model_parallel == 0, (devices, model_parallel)
    return make_mesh((devices // model_parallel, model_parallel),
                     ("data", "model"))


def mesh_from_cli(devices: int, model_parallel: int):
    """Launcher-side `--mesh N --model-parallel M` handling, shared by
    serve.py and train_gnn.py: validate the visible device count (with
    the CPU XLA_FLAGS hint) and build the (data, model) mesh."""
    import jax
    if jax.device_count() < devices:
        raise SystemExit(
            f"--mesh {devices} needs {devices} devices but jax sees "
            f"{jax.device_count()}; on CPU export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={devices}")
    return make_mesh_for(devices, model_parallel=model_parallel)

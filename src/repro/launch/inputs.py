"""ShapeDtypeStruct stand-ins for every model input (dry-run pattern:
weak-type-correct, shardable, no device allocation).

For [vlm]/[audio] archs the modality frontend is a stub per the
assignment: qwen2-vl receives precomputed patch embeddings (B,S,D) plus
(3,B,S) M-RoPE position ids; musicgen receives (B,S,4) EnCodec codebook
token ids (the EnCodec encoder itself is out of scope).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeSpec
from repro.models.config import ModelConfig
from repro.nn.layers import Axes


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Returns (avals, axes) pytrees for the given shape kind.

    train:   {tokens|embeddings[, positions], labels}
    prefill: {tokens|embeddings[, positions]}
    decode:  {tokens|embeddings[, positions], pos}   (+ caches, built by
             launch/dryrun.py via lm.cache_struct)
    """
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    avals: dict = {}
    axes: dict = {}
    if cfg.input_mode == "embeddings":
        avals["embeddings"] = _sds((b, s, cfg.d_model), cfg.cdtype)
        axes["embeddings"] = Axes(("act_batch", "act_seq", "act_embed"))
        if cfg.rope_kind == "mrope" and shape.kind != "decode":
            avals["positions"] = _sds((3, b, s), jnp.int32)
            axes["positions"] = Axes(("mrope3", "act_batch", "act_seq"))
    else:
        tshape = (b, s, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s)
        taxes = ("act_batch", "act_seq", "codebooks") if cfg.n_codebooks > 1 \
            else ("act_batch", "act_seq")
        avals["tokens"] = _sds(tshape, jnp.int32)
        axes["tokens"] = Axes(taxes)
    if shape.kind == "train":
        lshape = (b, shape.seq_len, cfg.n_codebooks) if cfg.n_codebooks > 1 \
            else (b, shape.seq_len)
        laxes = ("act_batch", "act_seq", "codebooks") if cfg.n_codebooks > 1 \
            else ("act_batch", "act_seq")
        avals["labels"] = _sds(lshape, jnp.int32)
        axes["labels"] = Axes(laxes)
    if shape.kind == "decode":
        avals["pos"] = _sds((), jnp.int32)
        axes["pos"] = Axes(())
    return avals, axes


def concrete_inputs(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
    """Small-scale REAL inputs with the same structure (for smoke tests)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    avals, axes = input_specs(cfg, shape)

    def materialize(sds):
        if jnp.issubdtype(sds.dtype, jnp.integer):
            hi = cfg.vocab_size if sds.shape else 2 ** 30
            return jnp.asarray(rng.integers(0, hi, sds.shape), sds.dtype)
        return jnp.asarray(rng.standard_normal(sds.shape), sds.dtype)

    return jax.tree.map(materialize, avals), axes

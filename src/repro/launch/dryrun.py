import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first
# backend initialization. Only the dry-run forces 512 placeholder host
# devices — tests/benches see the single real CPU device.
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")  # silence SPMD warnings

import argparse            # noqa: E402
import dataclasses         # noqa: E402
import json                # noqa: E402
import pathlib             # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402

from repro.configs.registry import (ARCHS, SHAPES, get_config,  # noqa: E402
                                    shape_applicable)
from repro.dist.hlo_analysis import analyze_collectives  # noqa: E402
from repro.dist.shardings import ShardingRules  # noqa: E402
from repro.launch.inputs import input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.training.optimizer import AdamWConfig, adamw_update, make_schedule  # noqa: E402
from repro.training.train_loop import (abstract_train_state,  # noqa: E402
                                       make_train_step, train_state_axes)

# ---------------------------------------------------------------------------
# Methodology (single-core container; see DESIGN.md §5):
#  * PROOF compile: the full-depth model, with layers under lax.scan
#    (stacked params) so XLA compiles the per-layer program once. This
#    proves every (arch × shape × mesh) lowers + compiles on the production
#    mesh and yields full-depth memory_analysis. cost_analysis of a scan
#    body is counted ONCE, so costs do NOT come from this artifact.
#  * COST lowering: the unrolled model at two reduced depths (L1=2p,
#    L2=4p; p = block-pattern period); every per-layer quantity (FLOPs,
#    bytes, collective traffic) is exactly linear in depth, so the full-
#    depth value is the 2-point linear extrapolation. Validated against an
#    exact full-depth unrolled compile (see EXPERIMENTS.md §Dry-run).
#  * decode/long shapes compile fast: proof == costs == exact full model.
# ---------------------------------------------------------------------------


def _reduced(cfg, k: int):
    pat = cfg.pattern[:k]
    return dataclasses.replace(cfg, n_layers=k, block_pattern=pat)


def _cost_depths(cfg) -> tuple[int, int] | None:
    p = lm.pattern_period(cfg)
    l1, l2 = 2 * p, 4 * p
    if cfg.n_layers <= l2:
        return None
    return l1, l2


def _build_step(cfg, shape, rules):
    """(fn, arg_avals, in_shardings, donate) for the unrolled model."""
    batch_avals, batch_axes = input_specs(cfg, shape)
    batch_sh = rules.tree_shardings(batch_avals, batch_axes)
    if shape.kind == "train":
        params_abs, opt_abs = abstract_train_state(cfg)
        p_axes, o_axes = train_state_axes(cfg)
        fn = make_train_step(cfg, AdamWConfig(), rules, remat=os.environ.get("DRYRUN_REMAT", "1") == "1")
        return (fn,
                (params_abs, opt_abs, batch_avals),
                (rules.tree_shardings(params_abs, p_axes),
                 rules.tree_shardings(opt_abs, o_axes),
                 batch_sh),
                (0, 1))
    params_abs = lm.abstract_params(cfg)
    p_sh = rules.tree_shardings(params_abs, lm.param_axes(cfg))
    if shape.kind == "prefill":
        def fn(params, batch):
            return lm.prefill(params, cfg, batch, shape.seq_len,
                              constrain=rules.constrain)
        return fn, (params_abs, batch_avals), (p_sh, batch_sh), ()
    cache_abs = lm.cache_struct(cfg, shape.global_batch, shape.seq_len,
                                abstract=True)
    cache_sh = rules.tree_shardings(cache_abs, lm.cache_axes(cfg))

    def fn(params, batch, caches):
        return lm.decode_step(params, cfg, batch, caches,
                              constrain=rules.constrain)

    return fn, (params_abs, batch_avals, cache_abs), (p_sh, batch_sh, cache_sh), (2,)


def _build_scanned(cfg, shape, rules):
    """Full-depth proof artifact with scanned layers."""
    batch_avals, batch_axes = input_specs(cfg, shape)
    batch_sh = rules.tree_shardings(batch_avals, batch_axes)
    params_abs, p_axes = lm.scanned_abstract_params(cfg)
    p_sh = rules.tree_shardings(params_abs, p_axes)
    if shape.kind == "train":
        f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jax.numpy.float32)
        opt_abs = {"m": jax.tree.map(f32, params_abs),
                   "v": jax.tree.map(f32, params_abs),
                   "step": jax.ShapeDtypeStruct((), jax.numpy.int32)}
        opt_sh = {"m": p_sh, "v": p_sh,
                  "step": rules.sharding((), ())}
        opt_cfg = AdamWConfig()
        sched = make_schedule(opt_cfg)

        def fn(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: lm.loss_fn_scanned(p, cfg, batch,
                                             constrain=rules.constrain,
                                             remat=True))(params)
            new_p, new_o, stats = adamw_update(grads, opt_state, params,
                                               opt_cfg, sched)
            return new_p, new_o, {"loss": loss, **stats}

        return fn, (params_abs, opt_abs, batch_avals), (p_sh, opt_sh, batch_sh), (0, 1)

    def fn(params, batch):  # prefill proof: full-sequence forward
        return lm.forward_scanned(params, cfg, batch, constrain=rules.constrain)

    return fn, (params_abs, batch_avals), (p_sh, batch_sh), ()


def _compile_once(fn, avals, shardings, donate, mesh):
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*avals)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # jax 0.4.x: list of dicts
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
    colls = analyze_collectives(hlo)
    return {
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "collectives": {
            "operand_bytes": colls.operand_bytes,
            "wire_bytes": colls.wire_bytes,
            "counts": colls.counts,
            "total_wire_bytes": colls.total_wire_bytes,
        },
        "hlo_lines": hlo.count("\n"),
    }


def _extrapolate(p1: dict, p2: dict, l1: int, l2: int, L: int) -> dict:
    def ext(v1, v2):
        return v2 + (L - l2) * (v2 - v1) / (l2 - l1)

    out = {
        "flops_per_device": ext(p1["flops_per_device"], p2["flops_per_device"]),
        "bytes_accessed_per_device": ext(p1["bytes_accessed_per_device"],
                                         p2["bytes_accessed_per_device"]),
    }
    coll = {"operand_bytes": {}, "wire_bytes": {}, "counts": {}}
    ops = set(p1["collectives"]["wire_bytes"]) | set(p2["collectives"]["wire_bytes"])
    for kind in ("operand_bytes", "wire_bytes", "counts"):
        for op in ops:
            v1 = p1["collectives"][kind].get(op, 0)
            v2 = p2["collectives"][kind].get(op, 0)
            coll[kind][op] = max(0.0, ext(v1, v2))
    coll["total_wire_bytes"] = sum(coll["wire_bytes"].values())
    out["collectives"] = coll
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path,
             overrides: dict | None = None, *, verbose: bool = True,
             tag: str = "", skip_proof: bool = False) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = shape_applicable(arch, shape_name)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind, "tag": tag,
        "params_total": cfg.num_params(),
        "params_active": cfg.active_params(),
        "n_layers": cfg.n_layers,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = out_dir / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        fname.write_text(json.dumps(rec, indent=2))
        if verbose:
            print(f"[skip] {arch} × {shape_name}: {why}", flush=True)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = ShardingRules(mesh)
    if overrides:
        rules = rules.override(**overrides)
    rec["devices"] = int(mesh.devices.size)
    try:
        if shape.kind == "decode":
            res = _compile_once(*_build_step(cfg, shape, rules), mesh)
            rec["proof"] = {"mode": "exact", "n_layers": cfg.n_layers,
                            "compile_s": res["compile_s"],
                            "memory": res["memory"]}
            rec["costs"] = {"mode": "exact", **{k: v for k, v in res.items()
                                                if k != "memory"}}
        else:
            depths = _cost_depths(cfg)
            if depths is None:
                res = _compile_once(*_build_step(cfg, shape, rules), mesh)
                rec["proof"] = {"mode": "exact", "n_layers": cfg.n_layers,
                                "compile_s": res["compile_s"],
                                "memory": res["memory"]}
                rec["costs"] = {"mode": "exact",
                                **{k: v for k, v in res.items() if k != "memory"}}
            else:
                l1, l2 = depths
                r1 = _compile_once(*_build_step(_reduced(cfg, l1), shape, rules), mesh)
                r2 = _compile_once(*_build_step(_reduced(cfg, l2), shape, rules), mesh)
                rec["costs"] = {
                    "mode": "extrapolated", "l1": l1, "l2": l2,
                    **_extrapolate(r1, r2, l1, l2, cfg.n_layers),
                    "points": {str(l1): r1, str(l2): r2},
                }
                if skip_proof:
                    rec["proof"] = {"mode": "skipped"}
                else:
                    pres = _compile_once(*_build_scanned(cfg, shape, rules), mesh)
                    rec["proof"] = {"mode": "scanned-full-depth",
                                    "n_layers": cfg.n_layers,
                                    "compile_s": pres["compile_s"],
                                    "memory": pres["memory"]}
        rec["status"] = "ok"
        if verbose:
            mem = rec["proof"].get("memory", {})
            mem_gib = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
                       - mem.get("alias_bytes", 0)) / 2 ** 30
            c = rec["costs"]
            print(f"[ok]  {arch:24s} {shape_name:12s} {mesh_kind:6s} "
                  f"flops/dev={c['flops_per_device']:.3e} "
                  f"coll={c['collectives']['total_wire_bytes'] / 2**20:9.1f}MiB "
                  f"mem/dev={mem_gib:6.2f}GiB "
                  f"({rec['costs'].get('mode', '?')[:5]}/"
                  f"{rec['proof'].get('mode', '?')[:7]})", flush=True)
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[ERR] {arch} × {shape_name} × {mesh_kind}: {rec['error']}",
                  flush=True)
    fname.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run driver")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="", help="suffix for result files")
    ap.add_argument("--skip-proof", action="store_true",
                    help="skip the full-depth scanned proof compile "
                         "(hillclimb iterations only need costs)")
    ap.add_argument("--override", action="append", default=[],
                    help="sharding rule override: logical=mesh1[+mesh2] or "
                         "logical= (empty => unsharded)")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        if not v:
            overrides[k] = ()
        else:
            overrides[k] = tuple(
                tuple(p.split("+")) if "+" in p else p for p in v.split(","))

    out_dir = pathlib.Path(args.out)
    t0 = time.time()
    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, out_dir,
                               overrides or None, tag=args.tag,
                               skip_proof=args.skip_proof)
                s = rec["status"]
                n_ok += s == "ok"
                n_skip += s == "skipped"
                n_err += s == "error"
    print(f"\ndone in {time.time() - t0:.0f}s: {n_ok} ok, {n_skip} skipped, "
          f"{n_err} errors", flush=True)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

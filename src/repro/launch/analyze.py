"""Static-analysis CLI: run every repro.analyze pass over the repo.

The CI gate::

    python -m repro.launch.analyze --fail-on error

What runs (all on scaled-down Table-II graphs so the gate stays fast):

  * **host-sync** — AST lint over the serving/runtime/kernels hot paths;
  * **plan**      — legality of the analytic ModelPlan for every zoo
    arch x Table-II dataset against the chosen backend's budget;
  * **retrace** / **dtype** — a compiled gcn Executable's jaxprs, plus
    (with ``--probe``, the default) live trace-stability of the jitted
    forward, the bucketed node-batch gather, and the ``runtime.fit``
    train step;
  * **comm**      — a sharded compile on a (data, model) mesh when >= 2
    devices are visible (CI forces 8 virtual host devices), recorded as
    an explicit skip otherwise.

Exit status is 1 when any finding reaches ``--fail-on`` severity
(``never`` disables the gate); ``--json`` emits the machine-readable
report for tooling.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

from repro.analyze import (Report, analyze_executable, ast_lint,
                           jaxpr_lint, plan_lint)
from repro.gnn.models import ARCHS, ZooSpec
from repro.graphs.datasets import TABLE2_DATASETS, make_dataset

# keeps every Table-II profile multi-shard but compile times in seconds
_SCALE = {"cora": 0.05, "citeseer": 0.02, "pubmed": 0.01}


def _spec_for(ds, arch: str, hidden: int = 8) -> ZooSpec:
    return ZooSpec(arch, ds.profile.feature_dim, hidden,
                   ds.profile.num_classes, num_layers=2)


def _plan_pass(report: Report, backend: str, max_n: int) -> None:
    from repro.gnn.executor import plan_model

    t0 = time.perf_counter()
    for name in sorted(TABLE2_DATASETS):
        ds = make_dataset(name, seed=0, scale=_SCALE[name])
        for arch in ARCHS:
            spec = _spec_for(ds, arch)
            plan = plan_model(spec, ds.profile.num_nodes,
                              ds.edges.shape[0], max_n=max_n)
            for f in plan_lint.check_model_plan(plan, backend_name=backend):
                report.add(dataclasses.replace(
                    f, location=f"{name}/{f.location}"))
    report.timings_ms["plan"] = (time.perf_counter() - t0) * 1e3


def _executable_pass(report: Report, backend: str, max_n: int,
                     probe: bool) -> None:
    from repro import runtime

    t0 = time.perf_counter()
    ds = make_dataset("cora", seed=0, scale=_SCALE["cora"])
    exe = runtime.compile(_spec_for(ds, "gcn"), ds, backend=backend,
                          max_shard_n=max_n)
    sub = analyze_executable(exe, probe=probe)
    sub.skipped.pop("host-sync", None)   # runs for real in main()
    sub.skipped.pop("comm", None)        # _comm_pass runs/records its own
    sub.timings_ms.clear()               # charged to this wall-clock below
    report.merge(sub)
    report.timings_ms["retrace+dtype"] = (time.perf_counter() - t0) * 1e3


def _fit_pass(report: Report, backend: str, max_n: int) -> None:
    """Trace-stability of the jitted train step: a short real fit must
    leave exactly one trace in the step cache."""
    from repro import runtime

    t0 = time.perf_counter()
    ds = make_dataset("cora", seed=0, scale=_SCALE["cora"])
    result = runtime.fit(_spec_for(ds, "gcn"), ds, steps=3,
                         backend=backend, max_shard_n=max_n,
                         log=lambda _msg: None)
    traces = jaxpr_lint.cache_size(result.trainable._jit_step)
    if traces is not None and traces > 1:
        from repro.analyze.report import Finding
        report.add(Finding(
            rule="RT003", severity="error", pass_name="retrace",
            message=f"3 full-batch train steps produced {traces} traces "
                    f"(expected 1); the train step recompiles per call",
            location="runtime.fit[gcn].step"))
    report.timings_ms["fit-retrace"] = (time.perf_counter() - t0) * 1e3


def _comm_pass(report: Report, backend: str, max_n: int,
               rtol: float) -> None:
    import jax

    n_dev = jax.device_count()
    if n_dev < 2:
        report.skipped["comm"] = (
            f"{n_dev} visible device(s): the comm pass needs a mesh "
            f"(CI sets XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return
    from repro import runtime
    from repro.analyze.hlo_lint import check_comm_stats
    from repro.launch.mesh import make_mesh_for

    t0 = time.perf_counter()
    mesh = make_mesh_for(n_dev - n_dev % 2, model_parallel=2)
    ds = make_dataset("cora", seed=0, scale=_SCALE["cora"])
    exe = runtime.compile(_spec_for(ds, "gcn"), ds, backend=backend,
                          max_shard_n=max_n, mesh=mesh)
    cs = exe.comm_stats()
    report.extend(check_comm_stats(
        cs, rtol=rtol,
        location=f"gcn data={cs['n_data']} model={cs['n_model']}"))
    report.timings_ms["comm"] = (time.perf_counter() - t0) * 1e3


def build_report(*, backend: str = "reference", max_n: int = 64,
                 probe: bool = True, rtol: float = 0.02,
                 fit_probe: bool = True) -> Report:
    """Run every pass over this checkout (see module docstring)."""
    report = Report()
    t0 = time.perf_counter()
    report.extend(ast_lint.lint_hot_paths())
    report.timings_ms["host-sync"] = (time.perf_counter() - t0) * 1e3

    _plan_pass(report, backend, max_n)
    _executable_pass(report, backend, max_n, probe)
    if fit_probe:
        _fit_pass(report, backend, max_n)
    _comm_pass(report, backend, max_n, rtol)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repro static-analysis gate (retrace, dtype, "
                    "host-sync, plan legality, comm contract)")
    ap.add_argument("--fail-on", default="error",
                    choices=("error", "warning", "info", "never"),
                    help="lowest severity that fails the gate "
                         "(default: error; 'never' always exits 0)")
    ap.add_argument("--backend", default="reference",
                    help="kernel backend analyzed/compiled against "
                         "(default: reference — CPU-fast)")
    ap.add_argument("--max-shard-n", type=int, default=64,
                    help="planner shard cap for the gate's tiny graphs")
    ap.add_argument("--rtol", type=float, default=0.02,
                    help="comm-contract relative tolerance")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the dynamic retrace probes (jit cache "
                         "oracle over real forwards + a short fit)")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable report")
    args = ap.parse_args(argv)

    report = build_report(backend=args.backend, max_n=args.max_shard_n,
                          probe=not args.no_probe, rtol=args.rtol,
                          fit_probe=not args.no_probe)
    print(json.dumps(report.to_json(), indent=2) if args.json
          else report.render())
    return 1 if report.failed(args.fail_on) else 0


if __name__ == "__main__":
    sys.exit(main())

"""GNN training launcher (`runtime.fit` end to end).

Full-batch on one device::

    PYTHONPATH=src python -m repro.launch.train_gnn --dataset cora \
        --arch gcn --steps 200 --backend reference

Neighbor-sampled mini-batches::

    PYTHONPATH=src python -m repro.launch.train_gnn --dataset citeseer \
        --arch sage_mean --steps 100 --batch-nodes 256 --fanout 10,5

Data-parallel over a device mesh (full-batch; gradients psum over the
shard_map transpose, collective volume verified against the HLO)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train_gnn --dataset cora \
        --arch gcn --steps 50 --mesh 8 --model-parallel 2 \
        --backend reference --verify-comm

``--ckpt-dir`` makes the run resumable: interrupt it, rerun the same
command, and it continues from the latest checkpoint to ``--steps``.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--arch", default="gcn")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--schedule", default="constant",
                    choices=["constant", "cosine", "wsd"])
    ap.add_argument("--scale", type=float, default=1.0,
                    help="dataset node/edge scale factor")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "jax", "reference", "ref"])
    ap.add_argument("--plan", choices=["analytic", "autotune"],
                    default="analytic",
                    help="layer-plan source: Table-I cost model, or "
                         "measured winners from the repro.tune autotuner")
    ap.add_argument("--tune-budget", type=int, default=8,
                    help="--plan autotune: max candidate plans measured")
    ap.add_argument("--shard-n", type=int, default=512)
    ap.add_argument("--batch-nodes", type=int, default=0,
                    help="0 trains full-batch; >0 neighbor-samples this "
                         "many seed nodes per step")
    ap.add_argument("--fanout", default="10,5",
                    help="comma per-layer neighbor sample counts")
    ap.add_argument("--mesh", type=int, default=0, metavar="DEVICES",
                    help="data-parallel full-batch training on a (data, "
                         "model) mesh over this many devices")
    ap.add_argument("--model-parallel", type=int, default=2)
    ap.add_argument("--verify-comm", action="store_true",
                    help="assert the train step's measured collective "
                         "volume against the forward all-gather model "
                         "(--mesh only)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint/resume directory (resumable runs)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--save-params", default=None,
                    help="write the trained weights to this .npz (loadable "
                         "via Executable.load_params for a serving reload)")
    args = ap.parse_args()

    from repro import runtime
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset

    mesh = None
    if args.mesh:
        from repro.launch.mesh import mesh_from_cli
        mesh = mesh_from_cli(args.mesh, args.model_parallel)
        print(f"mesh: data={args.mesh // args.model_parallel} x "
              f"model={args.model_parallel}")

    ds = make_dataset(args.dataset, seed=0, scale=args.scale)
    print(f"{ds.profile.name}: {ds.profile.num_nodes} nodes, "
          f"{ds.edges.shape[0]} edges, {ds.profile.feature_dim} features, "
          f"{int(ds.train_mask.sum())} train nodes")
    spec = ZooSpec(args.arch, ds.profile.feature_dim, args.hidden,
                   ds.profile.num_classes, num_layers=args.layers)

    fanout = tuple(int(f) for f in args.fanout.split(",") if f)
    t0 = time.time()
    result = runtime.fit(
        spec, ds, steps=args.steps, lr=args.lr,
        weight_decay=args.weight_decay, schedule=args.schedule,
        warmup_steps=max(0, args.steps // 20) if args.schedule != "constant"
        else 0,
        batch_nodes=args.batch_nodes, fanout=fanout,
        backend=args.backend, mesh=mesh, max_shard_n=args.shard_n,
        plan=args.plan, tune_budget=args.tune_budget,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        log_every=args.log_every)
    dt = time.time() - t0

    print(result.executable.summary())
    regime = (f"mini-batch({args.batch_nodes} seeds, fanout {fanout})"
              if args.batch_nodes else "full-batch")
    steps_run = len(result.history) and result.history[-1][0] + 1
    print(f"trained {args.arch} on {ds.profile.name} [{regime}] "
          f"{steps_run}/{args.steps} steps in {dt:.1f}s; "
          f"train accuracy {result.train_accuracy():.3f}")

    if mesh is not None and args.verify_comm:
        cs = result.trainable.verify_train_comm()
        wire = cs["measured_wire_bytes"]
        print("train-step collectives (wire bytes): "
              + ", ".join(f"{k}={v:.3g}" for k, v in sorted(wire.items())))
        print(f"forward all-gather model: "
              f"{cs['forward_allgather_wire_bytes']:.3g} B "
              f"(measured all-gather >= model: verified)")

    if args.save_params:
        result.executable.save_params(args.save_params)
        print(f"saved trained params to {args.save_params}")


if __name__ == "__main__":
    main()

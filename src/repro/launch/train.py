"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --steps 100 [--smoke] [--mesh single|multi|host]

On TPU hardware this builds the production mesh, shards the train state
per dist/shardings.py rules and runs the fault-tolerant TrainLoop. On this
CPU container use --smoke (reduced config, host mesh) — the full configs
are exercised via launch/dryrun.py instead.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the host devices")
    ap.add_argument("--mesh", default="host", choices=["single", "multi", "host"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    from repro.configs.registry import get_config, get_smoke
    from repro.checkpoint.manager import CheckpointManager
    from repro.dist.shardings import ShardingRules
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import (TrainLoop, init_train_state,
                                           make_train_step)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = AdamWConfig(lr=args.lr, schedule=args.schedule,
                          warmup_steps=max(5, args.steps // 20),
                          total_steps=args.steps)

    rules = None
    if args.mesh in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rules = ShardingRules(mesh)

    params, opt_state = init_train_state(cfg, opt_cfg, jax.random.key(0),
                                         compress_grads=args.compress_grads)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"mesh={args.mesh} steps={args.steps}")

    rng = np.random.default_rng(0)

    def data(step: int):
        r = np.random.default_rng(step)
        shape = (args.global_batch, args.seq)
        if cfg.n_codebooks > 1:
            shape += (cfg.n_codebooks,)
        toks = r.integers(0, cfg.vocab_size, shape)
        batch = {"labels": jax.numpy.asarray(toks, jax.numpy.int32)}
        if cfg.input_mode == "embeddings":
            batch["embeddings"] = jax.numpy.asarray(
                rng.standard_normal((args.global_batch, args.seq,
                                     cfg.d_model)), cfg.cdtype)
        else:
            batch["tokens"] = batch["labels"]
        return batch

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, rules,
                                      remat=not args.smoke,
                                      compress_grads=args.compress_grads),
                      donate_argnums=(0, 1))
    mgr = CheckpointManager(args.ckpt_dir, keep=3, async_save=True)
    loop = TrainLoop(cfg, opt_cfg, data, ckpt_manager=mgr, ckpt_every=50)
    loop.run(params, opt_state, args.steps, train_step=step_fn)


if __name__ == "__main__":
    main()

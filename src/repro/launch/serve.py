"""Production serving launcher. Two paths share it:

LM generation (default)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --num-requests 8 --prompt-len 32 --new-tokens 32

GNN node classification (repro.gnn zoo + GNNServeEngine)::

    PYTHONPATH=src python -m repro.launch.serve --mode gnn \
        --graphs cora,citeseer --models gcn,gat --num-requests 64
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _serve_lm(args) -> None:
    import jax

    from repro.configs.registry import get_config, get_smoke
    from repro.models import lm
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} needs frontend embeddings; serve "
                         f"token archs (see examples/serve_lm.py)")
    params = lm.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.new_tokens + 1)

    rng = np.random.default_rng(0)
    shape = (args.prompt_len, cfg.n_codebooks) if cfg.n_codebooks > 1 \
        else (args.prompt_len,)
    pending = [Request(rng.integers(0, cfg.vocab_size, shape).astype(np.int32),
                       max_new_tokens=args.new_tokens,
                       temperature=args.temperature)
               for _ in range(args.num_requests)]

    served = 0
    t0 = time.time()
    while pending:                      # simple FIFO batch scheduler
        batch, pending = pending[:args.batch_size], pending[args.batch_size:]
        outs = engine.generate(batch, seed=served)
        served += sum(o.shape[0] for o in outs)
        print(f"batch of {len(batch)} done ({served} tokens total)")
    dt = time.time() - t0
    print(f"served {args.num_requests} requests, {served} tokens "
          f"in {dt:.2f}s ({served / dt:.1f} tok/s)")


def _serve_gnn(args) -> None:
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

    graphs = [g.strip() for g in args.graphs.split(",") if g.strip()]
    models = [m.strip() for m in args.models.split(",") if m.strip()]

    from repro.graphs.datasets import DATASETS

    engine = GNNServeEngine(max_shard_n=args.shard_n, backend=args.backend)
    datasets = {}
    for g in graphs:
        # pre-check against the engine's densification limit BEFORE paying
        # for edge generation (full reddit: ~115M edges, minutes of work)
        est_nodes = int(DATASETS[g].num_nodes * args.scale)
        if est_nodes ** 2 * 4 > engine.max_dense_gib * 2 ** 30:
            raise SystemExit(
                f"graph {g!r} at scale {args.scale} (~{est_nodes} nodes) "
                f"exceeds the {engine.max_dense_gib} GiB dense-shard limit; "
                f"pass a smaller --scale")
        ds = make_dataset(g, seed=0, scale=args.scale)
        datasets[g] = ds
        engine.register_graph(g, ds)
        print(f"graph {g}: {ds.profile.num_nodes} nodes, "
              f"{ds.edges.shape[0]} edges, {ds.profile.feature_dim} features")

    for g in graphs:
        prof = datasets[g].profile
        for m in models:
            engine.register_model(
                f"{m}@{g}",
                ZooSpec(m, prof.feature_dim, args.hidden, prof.num_classes,
                        num_layers=args.layers, heads=args.heads),
                seed=0)

    rng = np.random.default_rng(1)
    reqs = []
    for _ in range(args.num_requests):
        g = graphs[int(rng.integers(len(graphs)))]
        m = models[int(rng.integers(len(models)))]
        n = datasets[g].profile.num_nodes
        ids = rng.integers(0, n, size=int(rng.integers(1, args.nodes_per_req + 1)))
        reqs.append(NodeRequest(graph=g, node_ids=ids, model=f"{m}@{g}"))

    t0 = time.time()
    for r in reqs:
        engine.submit(r)
    preds = engine.flush()
    dt = time.time() - t0
    for p in preds[:4]:
        print(f"  {p.model} on {p.graph}: nodes {p.node_ids[:5].tolist()} -> "
              f"classes {p.classes[:5].tolist()} "
              f"(p={np.round(p.probs[:5], 3).tolist()})")
    print(engine.cache_report())
    print(f"served {len(preds)} requests in {dt:.2f}s "
          f"({len(preds) / dt:.1f} req/s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "gnn"], default="lm")
    # LM path
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    # GNN path
    ap.add_argument("--graphs", default="cora")
    ap.add_argument("--models", default="gcn,gat")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "jax", "reference", "ref"],
                    help="kernel backend pinned into each compiled "
                         "Executable (default: REPRO_KERNEL_BACKEND env, "
                         "else pallas)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--heads", type=int, default=2)
    ap.add_argument("--shard-n", type=int, default=512)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--nodes-per-req", type=int, default=8)
    args = ap.parse_args()

    if args.mode == "gnn":
        _serve_gnn(args)
    else:
        _serve_lm(args)


if __name__ == "__main__":
    main()

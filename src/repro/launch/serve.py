"""Production serving launcher: one scheduler-driven path for both engines.

Both modes build a continuous-batching :class:`repro.serving.Server` over
their engine (the LM ``ServeEngine`` streams by prompt length, the GNN
``GNNServeEngine`` by (model, graph)); requests go in as tickets with
optional priority/deadline, micro-batches form under the hybrid
max-batch-size + max-wait policy, and outcomes come back typed
(Completed / Rejected / Expired) with per-request queue/engine latency.

LM generation (default)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --num-requests 8 --prompt-len 32 --new-tokens 32

GNN node classification (repro.gnn zoo + GNNServeEngine)::

    PYTHONPATH=src python -m repro.launch.serve --mode gnn \
        --graphs cora,citeseer --models gcn,gat --num-requests 64

Multi-device GNN serving (sharded Executables via repro.dist.gnn)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --mode gnn --mesh 8 \
        --model-parallel 2 --graphs cora --models gcn --backend reference
"""
from __future__ import annotations

import argparse
import time

import numpy as np

# NOTE: repro.serving (and through it jax + the model stack) is imported
# inside the helpers, keeping `--help` / arg errors fast.


def _make_server(engine, args):
    from repro.serving import SchedulerConfig, Server

    return Server(engine, SchedulerConfig(
        max_batch_size=args.batch_size,
        max_wait_ms=args.max_wait_ms,
        max_queue_depth=args.queue_depth))


def _submit(server, payload, stats: dict, **kw):
    """Closed-loop submit: on queue-full backpressure, drive the scheduler
    to make room and retry instead of silently dropping the request.
    Retries are counted in ``stats`` (each one shows up in the server's
    submitted/rejected totals)."""
    from repro.serving import Rejected

    while True:
        ticket = server.submit(payload, **kw)
        out = ticket.poll()
        if not (isinstance(out, Rejected) and out.kind == "backpressure"):
            return ticket
        if server.step(force=True) == 0:
            return ticket           # no progress possible; keep the reject
        stats["retries"] = stats.get("retries", 0) + 1


def _resolve(server, tickets) -> list:
    """Drain the scheduler and collect outcomes (submission order)."""
    server.drain()
    return [t.result() for t in tickets]


def _report(server, stats: dict) -> str:
    line = server.report()
    if stats.get("retries"):
        line += (f" | {stats['retries']} backpressure retries "
                 f"(counted in submitted/rejected)")
    return line


def _latency_line(outcomes) -> str:
    from repro.serving import Completed

    lat = [o.latency_ms for o in outcomes if isinstance(o, Completed)]
    if not lat:
        return "no completed requests"
    p50, p95, p99 = np.percentile(lat, [50, 95, 99])
    return f"latency p50 {p50:.2f} ms, p95 {p95:.2f} ms, p99 {p99:.2f} ms"


def _serve_lm(args) -> None:
    import jax

    from repro.configs.registry import get_config, get_smoke
    from repro.models import lm
    from repro.serving import Completed
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} needs frontend embeddings; serve "
                         f"token archs (see examples/serve_lm.py)")
    params = lm.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.new_tokens + 1)
    server = _make_server(engine, args)

    rng = np.random.default_rng(0)
    shape = (args.prompt_len, cfg.n_codebooks) if cfg.n_codebooks > 1 \
        else (args.prompt_len,)
    stats: dict = {}
    t0 = time.time()
    tickets = [_submit(
        server,
        Request(rng.integers(0, cfg.vocab_size, shape).astype(np.int32),
                max_new_tokens=args.new_tokens,
                temperature=args.temperature),
        stats)
        for _ in range(args.num_requests)]
    outcomes = _resolve(server, tickets)
    dt = time.time() - t0

    done = [o for o in outcomes if isinstance(o, Completed)]
    served = sum(o.value.shape[0] for o in done)
    print(_report(server, stats))
    print(_latency_line(outcomes))
    print(f"served {len(done)}/{args.num_requests} requests, {served} "
          f"tokens in {dt:.2f}s ({served / dt:.1f} tok/s)")


def _serve_gnn(args) -> None:
    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.serving import Completed
    from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

    graphs = [g.strip() for g in args.graphs.split(",") if g.strip()]
    models = [m.strip() for m in args.models.split(",") if m.strip()]

    from repro.graphs.datasets import DATASETS

    mesh = None
    if args.mesh:
        from repro.dist.gnn import SUPPORTED_ARCHS
        from repro.launch.mesh import mesh_from_cli

        bad = [m for m in models if m not in SUPPORTED_ARCHS]
        if bad:
            raise SystemExit(
                f"--mesh serving supports {SUPPORTED_ARCHS}; drop {bad} "
                f"from --models")
        mesh = mesh_from_cli(args.mesh, args.model_parallel)
        print(f"mesh: {args.mesh} devices as "
              f"data={args.mesh // args.model_parallel} x "
              f"model={args.model_parallel} (sharded Executables)")

    if args.plan == "autotune":
        print(f"plan source: autotune (budget {args.tune_budget} candidates "
              f"per (model, graph); winners memoized via REPRO_PLAN_CACHE)")
    engine = GNNServeEngine(max_shard_n=args.shard_n, backend=args.backend,
                            mesh=mesh, plan=args.plan,
                            tune_budget=args.tune_budget)
    datasets = {}
    for g in graphs:
        # pre-check against the engine's densification limit BEFORE paying
        # for edge generation (full reddit: ~115M edges, minutes of work)
        est_nodes = int(DATASETS[g].num_nodes * args.scale)
        if est_nodes ** 2 * 4 > engine.max_dense_gib * 2 ** 30:
            raise SystemExit(
                f"graph {g!r} at scale {args.scale} (~{est_nodes} nodes) "
                f"exceeds the {engine.max_dense_gib} GiB dense-shard limit; "
                f"pass a smaller --scale")
        ds = make_dataset(g, seed=0, scale=args.scale)
        datasets[g] = ds
        engine.register_graph(g, ds)
        print(f"graph {g}: {ds.profile.num_nodes} nodes, "
              f"{ds.edges.shape[0]} edges, {ds.profile.feature_dim} features")

    for g in graphs:
        prof = datasets[g].profile
        for m in models:
            engine.register_model(
                f"{m}@{g}",
                ZooSpec(m, prof.feature_dim, args.hidden, prof.num_classes,
                        num_layers=args.layers, heads=args.heads),
                seed=0)

    server = _make_server(engine, args)
    rng = np.random.default_rng(1)
    stats: dict = {}
    t0 = time.time()
    tickets = []
    for i in range(args.num_requests):
        g = graphs[int(rng.integers(len(graphs)))]
        m = models[int(rng.integers(len(models)))]
        n = datasets[g].profile.num_nodes
        ids = rng.integers(0, n, size=int(rng.integers(1, args.nodes_per_req + 1)))
        tickets.append(_submit(
            server, NodeRequest(graph=g, node_ids=ids, model=f"{m}@{g}"),
            stats,
            priority=1 if i % 8 == 0 else 0,
            deadline_ms=args.deadline_ms))
    outcomes = _resolve(server, tickets)
    dt = time.time() - t0

    done = [o.value for o in outcomes if isinstance(o, Completed)]
    for p in done[:4]:
        print(f"  {p.model} on {p.graph}: nodes {p.node_ids[:5].tolist()} -> "
              f"classes {p.classes[:5].tolist()} "
              f"(p={np.round(p.probs[:5], 3).tolist()})")
    print(engine.cache_report())
    print(_report(server, stats))
    print(_latency_line(outcomes))
    print(f"served {len(done)}/{len(tickets)} requests in {dt:.2f}s "
          f"({len(done) / dt:.1f} req/s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "gnn"], default="lm")
    # shared scheduler policy
    ap.add_argument("--batch-size", type=int, default=4,
                    help="scheduler max micro-batch size")
    ap.add_argument("--max-wait-ms", type=float, default=0.0,
                    help="oldest-entry wait that dispatches an underfull "
                         "batch (0 = dispatch immediately)")
    ap.add_argument("--queue-depth", type=int, default=256,
                    help="per-stream admission bound (backpressure)")
    # LM path
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    # GNN path
    ap.add_argument("--graphs", default="cora")
    ap.add_argument("--models", default="gcn,gat")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "jax", "reference", "ref"],
                    help="kernel backend pinned into each compiled "
                         "Executable (default: REPRO_KERNEL_BACKEND env, "
                         "else pallas)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--heads", type=int, default=2)
    ap.add_argument("--mesh", type=int, default=0, metavar="DEVICES",
                    help="serve from sharded Executables on a (data, "
                         "model) mesh over this many devices (0 = single "
                         "device; on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first)")
    ap.add_argument("--model-parallel", type=int, default=2,
                    help="model-axis size of the --mesh (data axis = "
                         "devices / model_parallel)")
    ap.add_argument("--plan", choices=["analytic", "autotune"],
                    default="analytic",
                    help="layer-plan source: Table-I cost model, or "
                         "measured winners from the repro.tune autotuner")
    ap.add_argument("--tune-budget", type=int, default=8,
                    help="--plan autotune: max candidate plans measured "
                         "per (model, graph)")
    ap.add_argument("--shard-n", type=int, default=512)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--nodes-per-req", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; queued past it -> Expired")
    args = ap.parse_args()

    if args.mode == "gnn":
        _serve_gnn(args)
    else:
        _serve_lm(args)


if __name__ == "__main__":
    main()

"""Production serving launcher: batched requests through ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --num-requests 8 --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs.registry import get_config, get_smoke
    from repro.models import lm
    from repro.serving.engine import Request, ServeEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} needs frontend embeddings; serve "
                         f"token archs (see examples/serve_lm.py)")
    params = lm.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params,
                         max_len=args.prompt_len + args.new_tokens + 1)

    rng = np.random.default_rng(0)
    shape = (args.prompt_len, cfg.n_codebooks) if cfg.n_codebooks > 1 \
        else (args.prompt_len,)
    pending = [Request(rng.integers(0, cfg.vocab_size, shape).astype(np.int32),
                       max_new_tokens=args.new_tokens,
                       temperature=args.temperature)
               for _ in range(args.num_requests)]

    served = 0
    t0 = time.time()
    while pending:                      # simple FIFO batch scheduler
        batch, pending = pending[:args.batch_size], pending[args.batch_size:]
        outs = engine.generate(batch, seed=served)
        served += sum(o.shape[0] for o in outs)
        print(f"batch of {len(batch)} done ({served} tokens total)")
    dt = time.time() - t0
    print(f"served {args.num_requests} requests, {served} tokens "
          f"in {dt:.2f}s ({served / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

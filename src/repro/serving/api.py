"""Transport-agnostic async serving core: Server, Ticket, typed outcomes.

The serving surface used to be two unrelated code paths — a synchronous
one-shot ``submit()``/``flush()`` on the GNN engine and a hand-rolled FIFO
loop around the LM engine in ``launch/serve.py``. This module unifies them
behind one request lifecycle:

    server = Server(engine, SchedulerConfig(max_batch_size=8))
    ticket = server.submit(request, priority=1, deadline_ms=50.0)
    ...
    server.drain()                       # or server.start() a driver thread
    outcome = ticket.result()            # Completed | Rejected | Expired | Failed
    if isinstance(outcome, Completed):
        use(outcome.value)               # queue_ms / engine_ms attached

Any engine that implements the two-method step protocol plugs in:

    class Engine(Protocol):
        def route(self, payload) -> Hashable:
            '''Validate one request and name the stream that batches it
            (GNN: the (model, graph) pair; LM: the prompt-length bucket).
            Raise to reject.'''
        def step(self, key, payloads: Sequence) -> Sequence:
            '''Run one formed micro-batch; results match payloads
            positionally. An Exception instance in the result list fails
            that request alone (typed Failed); raising fails the whole
            batch.'''

Batch formation, priority/EDF ordering, bounded admission and the
starvation guard live in :mod:`repro.serving.scheduler`; this module owns
the request lifecycle (tickets, outcomes, metrics) and the two drive
modes — cooperative (``step()``/``drain()``/``Ticket.result()`` drive the
scheduler inline) and threaded (``start()`` runs a background driver so
``submit`` is truly asynchronous). Engine steps run outside the queue
lock, so submissions never block behind compute.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Hashable, Protocol, Sequence, runtime_checkable

from repro.serving.scheduler import (MicroBatchScheduler, QueueEntry,
                                     SchedulerConfig)


@runtime_checkable
class Engine(Protocol):
    """The step protocol the scheduler drives (see module docstring)."""

    def route(self, payload) -> Hashable: ...

    def step(self, key, payloads: Sequence) -> Sequence: ...


# -- typed outcomes --------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Completed:
    """The engine answered: ``value`` is its result for this request."""

    value: Any
    queue_ms: float = 0.0       # admission -> batch dispatch
    engine_ms: float = 0.0      # this request's share of engine time

    @property
    def latency_ms(self) -> float:
        return self.queue_ms + self.engine_ms


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Refused at admission: invalid request or queue-full backpressure.

    ``kind`` is the machine-readable discriminator ("invalid" — the
    engine's route() raised — or "backpressure" — the stream queue is
    full, retrying after the server drains can succeed); ``reason`` is
    prose for humans.
    """

    reason: str
    kind: str = "invalid"


@dataclasses.dataclass(frozen=True)
class Expired:
    """The deadline passed while queued; the engine never ran it."""

    deadline_ms: float
    waited_ms: float


@dataclasses.dataclass(frozen=True)
class Failed:
    """The engine raised while running this request's micro-batch."""

    error: str


Outcome = Completed | Rejected | Expired | Failed


class Ticket:
    """Handle for one submitted request: ``poll()`` / ``result()``."""

    def __init__(self, server: "Server", ticket_id: int, priority: int,
                 deadline_ms: float | None, arrival_s: float):
        self.id = ticket_id
        self.priority = priority
        self.deadline_ms = deadline_ms
        self.arrival_s = arrival_s
        self._server = server
        self._event = threading.Event()
        self._outcome: Outcome | None = None

    def poll(self) -> Outcome | None:
        """Non-blocking: the outcome, or None while still queued/running."""
        return self._outcome

    @property
    def done(self) -> bool:
        return self._outcome is not None

    def result(self, timeout_s: float | None = None) -> Outcome:
        """Block until resolved. Cooperative mode drives the server's
        scheduler inline; with a driver thread running it just waits."""
        outcome = self._server._wait(self, timeout_s)
        if outcome is None:
            raise TimeoutError(f"ticket {self.id} unresolved after "
                               f"{timeout_s}s")
        return outcome

    def _resolve(self, outcome: Outcome) -> None:
        if self._outcome is not None:  # exactly-once is a core invariant
            raise RuntimeError(f"ticket {self.id} resolved twice")
        self._outcome = outcome
        self._event.set()


class Server:
    """Continuous-batching server over any :class:`Engine`."""

    def __init__(self, engine: Engine, config: SchedulerConfig | None = None,
                 *, clock=time.monotonic):
        self._engine = engine
        self._sched = MicroBatchScheduler(config)
        self._clock = clock
        self._cv = threading.Condition(threading.RLock())
        # serializes whole step() passes: engines are not required to be
        # thread-safe, so a driver thread and an inline step()/drain()
        # caller must never run engine.step concurrently
        self._step_lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._stopping = False
        self._ids = itertools.count()
        self._m = {"submitted": 0, "rejected": 0, "completed": 0,
                   "failed": 0, "reloads": 0, "queue_ms_total": 0.0,
                   "engine_ms_total": 0.0}

    @property
    def config(self) -> SchedulerConfig:
        return self._sched.config

    # -- request lifecycle -------------------------------------------------

    def submit(self, payload, *, priority: int = 0,
               deadline_ms: float | None = None) -> Ticket:
        """Admit one request; never raises for load or bad requests —
        the returned ticket resolves to a typed ``Rejected`` instead."""
        now = self._clock()
        ticket = Ticket(self, next(self._ids), priority, deadline_ms, now)
        with self._cv:
            self._m["submitted"] += 1
            try:
                key = self._engine.route(payload)
            except Exception as err:
                self._m["rejected"] += 1
                ticket._resolve(Rejected(f"{type(err).__name__}: {err}",
                                         kind="invalid"))
                return ticket
            entry = QueueEntry(
                payload=payload, ticket=ticket, priority=priority,
                arrival_s=now,
                deadline_s=None if deadline_ms is None
                else now + deadline_ms / 1e3)
            if not self._sched.push(key, entry):
                self._m["rejected"] += 1
                ticket._resolve(Rejected(
                    f"stream {key!r} at max queue depth "
                    f"{self._sched.config.max_queue_depth} (backpressure)",
                    kind="backpressure"))
                return ticket
            self._cv.notify_all()
        return ticket

    def step(self, *, force: bool = False) -> int:
        """Sweep expired entries, form one micro-batch and run it through
        the engine. Returns the number of tickets resolved (completed +
        expired + failed); 0 means nothing was dispatchable. Safe to call
        while a driver thread runs: step passes are serialized."""
        with self._step_lock:
            return self._step(force)

    def _step(self, force: bool) -> int:
        with self._cv:
            now = self._clock()
            expired = self._sched.sweep_expired(now)
            for e in expired:
                e.ticket._resolve(Expired(
                    deadline_ms=e.ticket.deadline_ms,
                    waited_ms=(now - e.arrival_s) * 1e3))
            formed = self._sched.next_batch(now, force=force)
            if formed is None:
                return len(expired)
            key, entries = formed
            dispatch_s = now
        payloads = [e.payload for e in entries]
        t0 = time.perf_counter()
        try:
            results = list(self._engine.step(key, payloads))
            if len(results) != len(entries):
                raise RuntimeError(
                    f"engine step returned {len(results)} results for "
                    f"{len(entries)} payloads on stream {key!r}")
        except Exception as err:
            with self._cv:
                self._m["failed"] += len(entries)
                for e in entries:
                    e.ticket._resolve(Failed(f"{type(err).__name__}: {err}"))
            return len(expired) + len(entries)
        batch_ms = (time.perf_counter() - t0) * 1e3
        with self._cv:
            for e, r in zip(entries, results):
                if isinstance(r, Exception):
                    # engines may fail a single request positionally (e.g.
                    # a stale node id) without poisoning its co-batch
                    self._m["failed"] += 1
                    e.ticket._resolve(Failed(f"{type(r).__name__}: {r}"))
                    continue
                queue_ms = (dispatch_s - e.arrival_s) * 1e3
                # engines that time each request (GNN Predictions) report
                # per-request engine_ms; otherwise charge the batch wall
                engine_ms = getattr(r, "engine_ms", None)
                engine_ms = batch_ms if engine_ms is None else engine_ms
                if hasattr(r, "queue_ms"):
                    r.queue_ms = queue_ms
                    if hasattr(r, "latency_ms"):
                        r.latency_ms = queue_ms + engine_ms
                e.ticket._resolve(Completed(
                    value=r, queue_ms=queue_ms, engine_ms=engine_ms))
                self._m["completed"] += 1
                self._m["queue_ms_total"] += queue_ms
                self._m["engine_ms_total"] += engine_ms
        return len(expired) + len(entries)

    def drain(self) -> int:
        """Run until every queue is empty (flushes underfull batches);
        returns the number of tickets resolved."""
        total = 0
        while True:
            n = self.step(force=True)
            total += n
            if n == 0:
                return total

    def queue_depth(self, key: Hashable | None = None) -> int:
        with self._cv:
            return self._sched.depth(key)

    def reload(self, apply_fn):
        """Hot engine update (e.g. a weight reload) serialized with engine
        steps: ``apply_fn(engine)`` runs under the step lock, so a
        micro-batch that is already inside the engine finishes on the old
        state, and every batch dispatched after the reload sees the new
        state — queued (in-flight) tickets are never Failed by the swap.

            server.reload(lambda eng: eng.reload_params("gcn", params))

        Returns ``apply_fn``'s result. Exceptions propagate to the caller
        (the engine was not modified on a validation error) and do not
        touch queued requests.
        """
        with self._step_lock:
            out = apply_fn(self._engine)
        with self._cv:
            self._m["reloads"] += 1
        return out

    # -- background driver (optional) --------------------------------------

    def start(self, poll_interval_s: float = 0.002, *,
              analyze: str | None = None) -> "Server":
        """Run a daemon driver thread so ``submit`` is fire-and-forget.

        ``analyze`` runs the static-analysis preflight
        (:func:`repro.analyze.preflight` — host-sync lint over the
        deployed hot paths plus every pass on each already-compiled
        Executable) before the driver starts: ``"warn"`` emits a
        ``UserWarning`` for warning-or-worse findings, ``"error"``
        refuses to start (raises :class:`repro.analyze.AnalysisError`)
        on any error finding — a misconfigured engine should fail at
        startup, not stall the queue at peak.
        """
        if analyze not in (None, "off", "warn", "error"):
            raise ValueError(f"analyze must be None, 'off', 'warn' or "
                             f"'error', got {analyze!r}")
        if analyze in ("warn", "error"):
            from repro import analyze as _analyze
            report = _analyze.preflight(self._engine)
            if analyze == "error" and report.failed("error"):
                raise _analyze.AnalysisError(report)
            if report.at_least("warning"):
                import warnings
                warnings.warn(f"serving preflight analysis:\n"
                              f"{report.render()}", stacklevel=2)
        if self._thread is None:
            self._stopping = False
            self._thread = threading.Thread(
                target=self._drive, args=(poll_interval_s,), daemon=True)
            self._thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        """Stop the driver thread (then flush what's left inline)."""
        if self._thread is not None:
            self._stopping = True
            with self._cv:
                self._cv.notify_all()
            self._thread.join()
            self._thread = None
        if drain:
            self.drain()

    def _drive(self, poll_interval_s: float) -> None:
        while not self._stopping:
            if self.step() == 0:
                with self._cv:
                    if self._stopping:
                        return
                    # short poll while work is queued but not yet
                    # dispatchable (max_wait window), long poll when idle
                    self._cv.wait(poll_interval_s if self._sched.depth()
                                  else 0.05)

    def _wait(self, ticket: Ticket, timeout_s: float | None) -> Outcome | None:
        if self._thread is not None:
            ticket._event.wait(timeout_s)
            return ticket._outcome
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        while ticket._outcome is None:
            if deadline is not None and time.monotonic() > deadline:
                return None
            # cooperative: result() is the driver; fall back to a forced
            # (flush) step so an underfull max_wait batch can't spin forever
            if self.step() == 0 and self.step(force=True) == 0 \
                    and ticket._outcome is None:
                raise RuntimeError(
                    f"server idle but ticket {ticket.id} unresolved")
        return ticket._outcome

    # -- observability -----------------------------------------------------

    def metrics(self) -> dict:
        """Queue/admission/latency counters (queue_ms/engine_ms are summed
        over completed requests; divide by ``completed`` for means)."""
        with self._cv:
            s = self._sched.stats
            return {**self._m,
                    "admitted": s["admitted"],
                    "expired": s["expired"],
                    "batches": s["batches"],
                    "dispatched": s["dispatched"],
                    "queue_depth": self._sched.depth(),
                    "peak_queue_depth": s["peak_depth"]}

    def report(self) -> str:
        m = self.metrics()
        mean_b = m["dispatched"] / m["batches"] if m["batches"] else 0.0
        mean_q = m["queue_ms_total"] / m["completed"] if m["completed"] else 0.0
        mean_e = m["engine_ms_total"] / m["completed"] if m["completed"] else 0.0
        return (f"server: {m['completed']}/{m['submitted']} completed, "
                f"{m['rejected']} rejected, {m['expired']} expired | "
                f"{m['batches']} batches (mean size {mean_b:.1f}, "
                f"peak queue depth {m['peak_queue_depth']}) | "
                f"mean queue {mean_q:.2f} ms, mean engine {mean_e:.2f} ms")

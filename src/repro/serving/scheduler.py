"""Continuous micro-batching scheduler: batch formation + queue policy.

This module is the pure policy half of the serving stack — it never touches
an engine. The :class:`~repro.serving.api.Server` pushes admitted requests
in and pops formed micro-batches out; everything in between is deterministic
given a clock:

  * **per-stream queues** — one priority queue per engine stream key (the
    GNN engine streams by (model, graph); the LM engine streams by prompt
    length). Within a stream, entries pop by descending ``priority``, then
    earliest absolute deadline (EDF), then arrival order — so equal-priority
    no-deadline traffic is strictly FIFO.
  * **hybrid formation policy** — a stream is dispatchable when it holds
    ``max_batch_size`` entries OR its oldest entry has waited
    ``max_wait_ms`` (0 means "form as soon as anything is queued"). The
    caller can ``force`` formation to flush underfull streams.
  * **bounded admission** — ``push`` refuses entries once a stream is
    ``max_queue_depth`` deep; the server surfaces that as a typed
    ``Rejected`` outcome (backpressure) instead of letting queues grow.
  * **starvation guard** — stream selection normally follows the best head
    entry (priority, then deadline, then arrival), which can starve a
    low-priority stream under sustained high-priority load; any stream
    whose head has waited ``starvation_ms`` preempts that ordering,
    oldest head first.
  * **expiry sweep** — entries whose deadline passed while queued are
    swept out and handed back so the server resolves them as ``Expired``
    rather than silently dropping (or worse, serving) them.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Any, Hashable


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Batch-formation and admission policy knobs.

    max_batch_size: micro-batch cap per dispatch.
    max_wait_ms: oldest-entry wait that makes an underfull stream
        dispatchable (0 = dispatch as soon as anything is queued).
    max_queue_depth: per-stream admission bound; pushes beyond it are
        refused (backpressure).
    starvation_ms: head wait beyond which a stream preempts the normal
        priority ordering.
    """

    max_batch_size: int = 8
    max_wait_ms: float = 0.0
    max_queue_depth: int = 256
    starvation_ms: float = 1000.0


@dataclasses.dataclass
class QueueEntry:
    """One queued request plus the bookkeeping the server resolves with."""

    payload: Any
    ticket: Any                     # resolved by the Server, opaque here
    priority: int = 0
    arrival_s: float = 0.0
    deadline_s: float | None = None  # absolute, on the server's clock
    seq: int = -1                    # admission order, assigned by push

    def sort_key(self) -> tuple:
        # higher priority first, then earliest deadline, then admission
        # order; seq is unique so heap tuples never compare entries
        dl = math.inf if self.deadline_s is None else self.deadline_s
        return (-self.priority, dl, self.seq)


class MicroBatchScheduler:
    """Per-stream priority queues + the hybrid formation policy."""

    def __init__(self, config: SchedulerConfig | None = None):
        self.config = config or SchedulerConfig()
        self._queues: dict[Hashable, list[tuple[tuple, QueueEntry]]] = {}
        # per-stream oldest arrival, maintained incrementally: push takes a
        # min, removals (dispatch / expiry) recompute once over what's
        # left. next_batch() reads it O(streams) instead of re-scanning
        # every queued entry (O(depth) per stream) on every tick.
        self._oldest: dict[Hashable, float] = {}
        self._seq = itertools.count()
        self._queued_deadlines = 0     # lets deadline-free sweeps short-circuit
        self.stats = {"admitted": 0, "rejected": 0, "expired": 0,
                      "dispatched": 0, "batches": 0, "peak_depth": 0}

    def depth(self, key: Hashable | None = None) -> int:
        if key is not None:
            return len(self._queues.get(key, ()))
        return sum(len(q) for q in self._queues.values())

    def streams(self) -> list[Hashable]:
        return [k for k, q in self._queues.items() if q]

    # -- admission ---------------------------------------------------------

    def push(self, key: Hashable, entry: QueueEntry) -> bool:
        """Admit ``entry`` to stream ``key``; False = stream full."""
        q = self._queues.setdefault(key, [])
        if len(q) >= self.config.max_queue_depth:
            self.stats["rejected"] += 1
            return False
        entry.seq = next(self._seq)
        heapq.heappush(q, (entry.sort_key(), entry))
        cur = self._oldest.get(key)
        self._oldest[key] = entry.arrival_s if cur is None \
            else min(cur, entry.arrival_s)
        if entry.deadline_s is not None:
            self._queued_deadlines += 1
        self.stats["admitted"] += 1
        self.stats["peak_depth"] = max(self.stats["peak_depth"], self.depth())
        return True

    # -- expiry ------------------------------------------------------------

    def sweep_expired(self, now: float) -> list[QueueEntry]:
        """Remove and return every queued entry whose deadline has passed
        (the server resolves them as Expired — they must not vanish)."""
        if not self._queued_deadlines:  # deadline-free traffic: no rebuild
            return []
        expired: list[QueueEntry] = []
        for key in list(self._queues):
            q = self._queues[key]
            live = [(k, e) for k, e in q
                    if e.deadline_s is None or e.deadline_s > now]
            if len(live) != len(q):
                expired.extend(e for k, e in q
                               if e.deadline_s is not None
                               and e.deadline_s <= now)
                heapq.heapify(live)
                if live:
                    self._queues[key] = live
                    self._oldest[key] = min(e.arrival_s for _, e in live)
                else:
                    del self._queues[key]
                    self._oldest.pop(key, None)
        self._queued_deadlines -= len(expired)
        self.stats["expired"] += len(expired)
        return expired

    # -- formation ---------------------------------------------------------

    def _head_wait_ms(self, key: Hashable, now: float) -> float:
        return (now - self._oldest[key]) * 1e3

    def next_batch(self, now: float, *, force: bool = False
                   ) -> tuple[Hashable, list[QueueEntry]] | None:
        """Form one micro-batch, or None when no stream is dispatchable.

        ``force`` flushes underfull streams regardless of ``max_wait_ms``
        (drain semantics).
        """
        cfg = self.config
        waits = {key: self._head_wait_ms(key, now)  # O(1) per stream
                 for key, q in self._queues.items() if q}
        ready = [key for key, q in self._queues.items() if q
                 and (force or len(q) >= cfg.max_batch_size
                      or waits[key] >= cfg.max_wait_ms)]
        if not ready:
            return None
        starving = [k for k in ready if waits[k] >= cfg.starvation_ms]
        if starving:
            key = max(starving, key=waits.__getitem__)
        else:
            key = min(ready, key=lambda k: self._queues[k][0][0])
        q = self._queues[key]
        batch = [heapq.heappop(q)[1]
                 for _ in range(min(cfg.max_batch_size, len(q)))]
        if not q:
            del self._queues[key]
            del self._oldest[key]
        else:
            self._oldest[key] = min(e.arrival_s for _, e in q)
        self._queued_deadlines -= sum(e.deadline_s is not None
                                      for e in batch)
        self.stats["batches"] += 1
        self.stats["dispatched"] += len(batch)
        return key, batch

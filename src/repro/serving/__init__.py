from repro.serving.api import (Completed, Engine, Expired, Failed, Outcome,
                               Rejected, Server, Ticket)
from repro.serving.engine import Request, ServeEngine
from repro.serving.gnn_engine import GNNServeEngine, NodeRequest, Prediction
from repro.serving.scheduler import MicroBatchScheduler, SchedulerConfig

__all__ = [
    "Server", "Ticket", "Engine", "Outcome",
    "Completed", "Rejected", "Expired", "Failed",
    "SchedulerConfig", "MicroBatchScheduler",
    "ServeEngine", "Request",
    "GNNServeEngine", "NodeRequest", "Prediction",
]

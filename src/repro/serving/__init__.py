from repro.serving.engine import ServeEngine
from repro.serving.gnn_engine import GNNServeEngine, NodeRequest, Prediction

__all__ = ["ServeEngine", "GNNServeEngine", "NodeRequest", "Prediction"]

from repro.serving.engine import ServeEngine  # noqa: F401
from repro.serving.gnn_engine import (GNNServeEngine, NodeRequest,  # noqa: F401
                                      Prediction)

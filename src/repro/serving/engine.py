"""Batched serving engine: prefill + incremental decode over the unified
LM's per-layer caches (KV ring buffers for local attention, recurrent
states for RG-LRU/SSD).

Requests are grouped into fixed batch slots; a batch prefills together
(prompts padded to the bucket length with left-padding-free semantics:
shorter prompts simply start decoding earlier positions — their extra
prefill logits are ignored) and then decodes lock-step with per-request
stop lengths. Greedy or temperature sampling.

The engine also implements the serving :class:`~repro.serving.api.Engine`
step protocol — ``route`` buckets requests by prompt length (``generate``
requires equal-length prompts per batch), ``step`` runs one formed
micro-batch — so the continuous-batching
:class:`~repro.serving.api.Server` drives it interchangeably with the GNN
engine.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32 [or (S, C) for codebooks]
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy


@dataclasses.dataclass
class ServeEngine:
    cfg: ModelConfig
    params: dict
    max_len: int = 512

    def __post_init__(self):
        cfg = self.cfg

        def _prefill(params, batch):
            return lm.prefill(params, cfg, batch, self.max_len)

        def _decode(params, batch, caches):
            return lm.decode_step(params, cfg, batch, caches)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._step_seed = 0

    # -- Engine step protocol (what the Server drives) ---------------------

    def route(self, req: Request) -> int:
        """Validate one request and name its stream: the prompt-length
        bucket, since a batch prefills at one padded length."""
        plen = len(req.prompt)
        if plen == 0:
            raise ValueError("empty prompt")
        if plen + req.max_new_tokens + 1 > self.max_len:
            raise ValueError(
                f"prompt ({plen}) + max_new_tokens ({req.max_new_tokens}) "
                f"exceeds engine max_len {self.max_len}")
        return plen

    def step(self, key: int, requests: Sequence[Request]) -> list:
        """Run one formed micro-batch (all prompts are length ``key``)."""
        seed, self._step_seed = self._step_seed, self._step_seed + 1
        return self.generate(list(requests), seed=seed)

    def generate(self, requests: Sequence[Request], seed: int = 0):
        """Serve one batch of equal-or-shorter prompts. Returns a list of
        generated token arrays."""
        cfg = self.cfg
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        assert all(len(r.prompt) == plen for r in requests), \
            "batch requests by equal prompt length (bucketing upstream)"
        multi = cfg.n_codebooks > 1
        shape = (b, plen, cfg.n_codebooks) if multi else (b, plen)
        toks = np.zeros(shape, np.int32)
        for i, r in enumerate(requests):
            toks[i, :len(r.prompt)] = r.prompt
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})

        key = jax.random.key(seed)
        outs: list[list] = [[] for _ in requests]
        cur = self._sample(logits[:, 0], requests, key)  # (B,) or (B,C)
        max_new = max(r.max_new_tokens for r in requests)
        for step in range(max_new):
            for i, r in enumerate(requests):
                if step < r.max_new_tokens:
                    outs[i].append(np.asarray(cur[i]))
            if step == max_new - 1:
                break
            key, sub = jax.random.split(key)
            batch = {"tokens": cur[:, None] if not multi else cur[:, None, :],
                     "pos": jnp.int32(plen + step)}
            logits, caches = self._decode(self.params, batch, caches)
            cur = self._sample(logits[:, 0], requests, sub)
        return [np.stack(o) for o in outs]

    def _sample(self, logits, requests, key):
        # logits: (B, V) or (B, C, V)
        greedy = jnp.argmax(logits, axis=-1)
        # request temperatures are host data — deciding the greedy fast
        # path on them must not round-trip a device reduction per decode
        # step (float(jnp.max(...)) here was a per-token host sync)
        temps_host = np.asarray([r.temperature for r in requests],
                                np.float32)
        if temps_host.max() == 0.0:
            return greedy.astype(jnp.int32)
        temps = jnp.asarray(temps_host)
        t = jnp.maximum(temps, 1e-4)
        while t.ndim < logits.ndim - 1:
            t = t[:, None]
        sampled = jax.random.categorical(key, logits / t[..., None], axis=-1)
        return jnp.where((temps <= 0)[:, None] if logits.ndim == 3
                         else temps <= 0, greedy, sampled).astype(jnp.int32)

"""GNN node-classification engine: the compile/cache core under the Server.

Requests name a registered graph + model and a set of node ids. The engine
implements the serving :class:`~repro.serving.api.Engine` step protocol —
``route`` validates a request and streams it by (model, graph), ``step``
answers one formed micro-batch from a compiled
:class:`repro.runtime.Executable`, cached per (model, graph) — so the
continuous-batching :class:`~repro.serving.api.Server` can drive it
interchangeably with the LM engine. The two serving caches are both
runtime-owned:

  * **graph-tensor cache** — the engine owns a private
    :class:`repro.runtime.GraphStore`; ``runtime.compile`` pulls each
    Executable's sharded, normalization-baked ``GraphTensors`` (+
    shard-grouped features) from it, keyed on ``(graph, normalize,
    self_loops, shard_n)`` — the signature
    :func:`repro.gnn.models.graph_signature` assigns each architecture —
    so every model needing the same signature shares one entry.
    LRU-evicted at a configurable capacity.
  * **logits cache** — full-graph inference is the natural unit on an
    accelerator (one shard-grid sweep per layer covers every node), so
    each Executable computes class probabilities for ALL nodes once
    (:meth:`Executable.full_probs`); every later node id on that pair is
    a pure gather. Invalidate with :meth:`GNNServeEngine.invalidate`
    after a weight swap.

Latency accounting is per request: ``Prediction.engine_ms`` is the time
spent answering THAT request (the cold full-graph forward is charged to
the request that triggered it, later requests pay only their gather);
compile time is never folded into request latency — it accrues to
``stats["compile_ms_total"]``. ``queue_ms`` is stamped by the Server.

The pre-Server one-shot API (``submit()``/``flush()``) remains as a thin
synchronous shim emitting ``DeprecationWarning``; ``serve()`` stays as the
synchronous batch core the shim and the Server path share.

Layer execution plans come from the content-hash-memoized planner inside
``runtime.compile`` — block size B, traversal order and fused/two-stage
per layer from the Table-I cost model, shard size from the on-chip budget.

Passing ``mesh=`` (a ``(data, model)`` jax mesh from
``launch.mesh.make_mesh_for``) makes every compiled unit a sharded
:class:`repro.dist.gnn.ShardedExecutable`: same serving protocol, forward
computed across the mesh (``launch/serve.py --mesh N`` wires this up).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro import runtime
from repro.gnn.executor import ModelPlan
from repro.gnn.models import ZooSpec, init_zoo
from repro.graphs.datasets import GraphData


@dataclasses.dataclass
class NodeRequest:
    """Classify ``node_ids`` of ``graph`` with ``model``."""

    graph: str
    node_ids: np.ndarray            # (k,) int
    model: str = "gcn"


@dataclasses.dataclass
class Prediction:
    graph: str
    model: str
    node_ids: np.ndarray
    classes: np.ndarray             # (k,) int32 argmax class per node
    probs: np.ndarray               # (k,) float32 softmax mass of the argmax
    queue_ms: float = 0.0           # admission -> dispatch (Server-stamped)
    engine_ms: float = 0.0          # THIS request's engine time
    latency_ms: float = 0.0         # queue_ms + engine_ms (back-compat)


@dataclasses.dataclass
class _ModelEntry:
    spec: ZooSpec
    params: dict


class GNNServeEngine:
    """Batched node-classification inference over named graphs/models."""

    def __init__(self, *, max_graph_entries: int = 8,
                 max_shard_n: int = 1024, max_dense_gib: float = 8.0,
                 backend: str | None = None, mesh=None,
                 plan: str = "analytic", tune_budget: int = 16):
        if plan not in ("analytic", "autotune"):
            raise ValueError(f"plan must be 'analytic' or 'autotune', "
                             f"got {plan!r}")
        if plan == "autotune" and mesh is not None:
            raise ValueError("plan='autotune' cannot tune sharded (mesh=) "
                             "execution; use plan='analytic' with mesh")
        self._graphs: dict[str, GraphData] = {}
        self._models: dict[str, _ModelEntry] = {}
        self._store = runtime.GraphStore(max_entries=max_graph_entries)
        # plan source every compiled unit uses: "analytic" (Table-I cost
        # model) or "autotune" (measured winners, repro.tune) — the first
        # request on a (model, graph) pair pays the tuning run, later
        # compiles hit the winner store
        self.plan_source = plan
        self.tune_budget = tune_budget
        # a (data, model) jax mesh: compiled units become sharded
        # Executables (repro.dist.gnn) serving from every device
        self.mesh = mesh
        # compiled (model, graph) units; each owns the full-graph softmax
        # that warm requests gather from
        self._executables: dict[tuple[str, str], runtime.Executable] = {}
        self._pending: list[NodeRequest] = []
        self.max_shard_n = max_shard_n
        self.max_dense_gib = max_dense_gib
        self.backend = backend
        self._stats = {
            "logits_cache_hits": 0, "logits_cache_misses": 0,
            "requests": 0, "batches": 0, "nodes_served": 0,
            "compiles": 0, "compile_ms_total": 0.0,
            "reloads": 0, "logits_invalidations": 0,
        }

    @property
    def stats(self) -> dict:
        """Serving counters merged with the runtime graph-store counters
        (kept under the historical key names)."""
        s = self._store.stats
        return {**self._stats,
                "graph_cache_hits": s["hits"],
                "graph_cache_misses": s["misses"],
                "graph_cache_evictions": s["evictions"],
                "graph_built_ms_total": s["built_ms_total"]}

    # -- registration ------------------------------------------------------

    def register_graph(self, name: str, data: GraphData) -> None:
        # fail fast before sharding: densified shard blocks cost
        # (padded N)² · 4 bytes, which for e.g. full-scale reddit is ~200 TiB
        n_pad = -(-data.profile.num_nodes // self.max_shard_n) * self.max_shard_n
        est_bytes = n_pad ** 2 * 4
        if est_bytes > self.max_dense_gib * 2 ** 30:
            raise ValueError(
                f"graph {name!r} ({data.profile.num_nodes} nodes) would "
                f"densify to ~{est_bytes / 2**30:.0f} GiB of shard blocks "
                f"(limit {self.max_dense_gib} GiB); register a scaled-down "
                f"dataset (make_dataset(..., scale=...)) or raise "
                f"max_dense_gib")
        self._graphs[name] = data
        # stale sharded tensors / executables for a replaced graph must go
        self._store.evict(name)
        for key in [k for k in self._executables if k[1] == name]:
            del self._executables[key]

    def register_model(self, name: str, spec: ZooSpec,
                       params: dict | None = None, *, seed: int = 0) -> None:
        if params is None:
            import jax
            params = init_zoo(jax.random.key(seed), spec)
        self._models[name] = _ModelEntry(spec=spec, params=params)
        # a (re-)registered model invalidates its compiled units wholesale:
        # the spec (and thus plan/graph signature) may have changed
        for key in [k for k in self._executables if k[0] == name]:
            del self._executables[key]

    def invalidate(self, *, model: str | None = None,
                   graph: str | None = None) -> None:
        """Drop cached logits (e.g. after a parameter update)."""
        for (m, g), exe in self._executables.items():
            if (model is None or m == model) and (graph is None or g == graph):
                exe.invalidate()

    def reload_params(self, model: str, params: dict) -> int:
        """Hot weight reload: swap ``model``'s parameters into every
        compiled Executable **without recompiling** (same shapes, same jit
        traces — :meth:`Executable.update_params` validates the tree).
        Each affected Executable's logits cache is invalidated exactly
        once, as part of the swap; later compiles on new graphs adopt the
        new weights too.

        Thread-safety is the Server's job: drive this through
        :meth:`repro.serving.Server.reload` so the swap is serialized
        with engine steps — the in-flight micro-batch finishes on the old
        weights, every later batch sees the new ones.
        """
        from repro.runtime.executable import validate_params_like

        ent = self._models[model]          # KeyError for unknown models
        # validate against the registered params BEFORE touching any
        # Executable, so a bad reload is all-or-nothing even when several
        # compiled units (or none yet) hold the model
        try:
            validate_params_like(ent.params, params)
        except ValueError as err:
            raise ValueError(
                f"reload for model {model!r} rejected: {err}") from None
        touched = 0
        for (m, _g), exe in self._executables.items():
            if m == model:
                exe.update_params(params)  # same-shape swap; invalidates once
                touched += 1
        ent.params = params
        self._stats["reloads"] += 1
        self._stats["logits_invalidations"] += touched
        return touched

    # -- compile path ------------------------------------------------------

    def executable(self, model: str, graph: str) -> runtime.Executable:
        """Fetch-or-compile the Executable serving a (model, graph) pair.

        Compile time accrues to ``stats["compile_ms_total"]`` — it is a
        per-(model, graph) setup cost, never charged to request latency.
        """
        key = (model, graph)
        exe = self._executables.get(key)
        if exe is None:
            ent = self._models[model]
            t0 = time.perf_counter()
            exe = runtime.compile(
                ent.spec, self._graphs[graph], params=ent.params,
                backend=self.backend, max_shard_n=self.max_shard_n,
                store=self._store, graph_key=graph, mesh=self.mesh,
                plan=self.plan_source, tune_budget=self.tune_budget)
            self._executables[key] = exe
            self._stats["compiles"] += 1
            self._stats["compile_ms_total"] += \
                (time.perf_counter() - t0) * 1e3
        return exe

    def model_plan(self, model: str, graph: str) -> ModelPlan:
        """The layer-execution plan a (model, graph) pair is compiled with."""
        return self.executable(model, graph).plan

    # -- Engine step protocol (what the Server drives) ---------------------

    def route(self, req: NodeRequest) -> tuple[str, str]:
        """Validate one request and name its stream: the (model, graph)
        pair, so the scheduler micro-batches work that shares an
        Executable (and its cached full-graph softmax)."""
        if req.model not in self._models:
            raise KeyError(f"unknown model {req.model!r}")
        if req.graph not in self._graphs:
            raise KeyError(f"unknown graph {req.graph!r}")
        if self.mesh is not None:
            # sharded execution covers the linear-aggregation family only;
            # reject HERE (admission -> typed Rejected on the ticket)
            # instead of letting runtime.compile raise inside step(),
            # which would Fail every co-batched request on the stream
            from repro.dist.gnn import SUPPORTED_ARCHS
            arch = self._models[req.model].spec.arch
            if arch not in SUPPORTED_ARCHS:
                raise NotImplementedError(
                    f"model {req.model!r} ({arch}) cannot run on a mesh: "
                    f"sharded execution supports {SUPPORTED_ARCHS}")
        ids = np.asarray(req.node_ids, dtype=np.int64)
        n_nodes = self._graphs[req.graph].profile.num_nodes
        if ids.size and (ids.min() < 0 or ids.max() >= n_nodes):
            raise IndexError(f"node ids out of range for graph "
                             f"{req.graph!r} ({n_nodes} nodes)")
        return (req.model, req.graph)

    def step(self, key: tuple[str, str],
             payloads: Sequence[NodeRequest]) -> list:
        """Answer one formed micro-batch (all requests share ``key``'s
        Executable). Results match ``payloads`` positionally; a request
        whose node ids went stale between admission and dispatch (graph
        re-registered smaller) yields its ValueError positionally so the
        Server fails THAT ticket alone — co-batched valid requests still
        complete."""
        model, graph = key
        exe = self.executable(model, graph)
        checked: list[np.ndarray | Exception] = []
        for r in payloads:
            try:
                checked.append(exe._check_node_ids(r.node_ids))
            except ValueError as err:
                checked.append(err)
        id_batches = [ids for ids in checked
                      if not isinstance(ids, Exception)]
        # one cache touch per VALID request (stale-id requests never reach
        # the cache): the batch's first touch may compute the full-graph
        # softmax, the rest count as hits
        miss = 0 if exe.has_cached_probs or not id_batches else 1
        self._stats["logits_cache_misses"] += miss
        self._stats["logits_cache_hits"] += len(id_batches) - miss
        answers = iter(exe.step(id_batches))
        out: list = []
        for ids in checked:
            if isinstance(ids, Exception):
                out.append(ids)
                continue
            classes, probs, ms = next(answers)
            out.append(Prediction(
                graph=graph, model=model, node_ids=ids, classes=classes,
                probs=probs, engine_ms=ms, latency_ms=ms))
            self._stats["requests"] += 1
            self._stats["nodes_served"] += int(ids.size)
        self._stats["batches"] += 1
        return out

    # -- synchronous batch core --------------------------------------------

    def serve(self, requests: Sequence[NodeRequest]) -> list[Prediction]:
        """Serve a batch synchronously; answers keep the caller's request
        order. (The async path is ``repro.serving.Server.submit`` — this
        core micro-batches by (model, graph) without queueing.)"""
        # validate everything before touching caches/stats so a bad request
        # rejects the batch atomically instead of half-serving it
        groups: OrderedDict[tuple[str, str], list[int]] = OrderedDict()
        for i, r in enumerate(requests):
            groups.setdefault(self.route(r), []).append(i)

        out: list[Prediction | None] = [None] * len(requests)
        for key, idxs in groups.items():
            preds = self.step(key, [requests[j] for j in idxs])
            for i, pred in zip(idxs, preds):
                out[i] = pred
        return out  # type: ignore[return-value]

    # -- deprecated one-shot shim ------------------------------------------

    def submit(self, req: NodeRequest) -> None:
        """Deprecated: queue one request for the next ``flush()``."""
        warnings.warn(
            "GNNServeEngine.submit/flush are deprecated; submit through "
            "repro.serving.Server for scheduled, ticketed serving",
            DeprecationWarning, stacklevel=2)
        self._pending.append(req)

    def flush(self) -> list[Prediction]:
        """Deprecated: serve all pending requests, micro-batched by
        (model, graph).

        The queue is cleared only on success: a rejected batch (unknown
        name, bad node ids) leaves every request queued for the caller to
        repair or drop."""
        warnings.warn(
            "GNNServeEngine.submit/flush are deprecated; submit through "
            "repro.serving.Server for scheduled, ticketed serving",
            DeprecationWarning, stacklevel=2)
        preds = self.serve(self._pending)
        self._pending = []
        return preds

    def cache_report(self) -> str:
        s = self.stats
        g_tot = s["graph_cache_hits"] + s["graph_cache_misses"]
        l_tot = s["logits_cache_hits"] + s["logits_cache_misses"]
        return (f"graph-tensor cache: {s['graph_cache_hits']}/{g_tot} hits "
                f"({len(self._store)} resident, "
                f"{s['graph_cache_evictions']} evicted, "
                f"{s['graph_built_ms_total']:.0f} ms building) | "
                f"logits cache: {s['logits_cache_hits']}/{l_tot} hits | "
                f"{s['compiles']} executables compiled "
                f"({s['compile_ms_total']:.0f} ms) | "
                f"{s['requests']} requests, {s['nodes_served']} nodes in "
                f"{s['batches']} batches")

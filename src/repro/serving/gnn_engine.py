"""Batched GNN node-classification serving over compiled Executables.

Requests name a registered graph + model and a set of node ids; the engine
groups pending requests by (model, graph) into micro-batches and answers
each batch from a compiled :class:`repro.runtime.Executable`, cached per
(model, graph). The two serving caches are now both runtime-owned:

  * **graph-tensor cache** — the engine owns a private
    :class:`repro.runtime.GraphStore`; ``runtime.compile`` pulls each
    Executable's sharded, normalization-baked ``GraphTensors`` (+
    shard-grouped features) from it, keyed on ``(graph, normalize,
    self_loops, shard_n)`` — the signature
    :func:`repro.gnn.models.graph_signature` assigns each architecture —
    so every model needing the same signature shares one entry.
    LRU-evicted at a configurable capacity.
  * **logits cache** — full-graph inference is the natural unit on an
    accelerator (one shard-grid sweep per layer covers every node), so
    each Executable computes class probabilities for ALL nodes once
    (:meth:`Executable.full_probs`); every later node id on that pair is
    a pure gather. Invalidate with :meth:`GNNServeEngine.invalidate`
    after a weight swap.

Layer execution plans come from the content-hash-memoized planner inside
``runtime.compile`` — block size B, traversal order and fused/two-stage
per layer from the Table-I cost model, shard size from the on-chip budget.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro import runtime
from repro.gnn.executor import ModelPlan
from repro.gnn.models import ZooSpec, init_zoo
from repro.graphs.datasets import GraphData


@dataclasses.dataclass
class NodeRequest:
    """Classify ``node_ids`` of ``graph`` with ``model``."""

    graph: str
    node_ids: np.ndarray            # (k,) int
    model: str = "gcn"


@dataclasses.dataclass
class Prediction:
    graph: str
    model: str
    node_ids: np.ndarray
    classes: np.ndarray             # (k,) int32 argmax class per node
    probs: np.ndarray               # (k,) float32 softmax mass of the argmax
    latency_ms: float               # engine time for the micro-batch


@dataclasses.dataclass
class _ModelEntry:
    spec: ZooSpec
    params: dict


class GNNServeEngine:
    """Batched node-classification inference over named graphs/models."""

    def __init__(self, *, max_graph_entries: int = 8,
                 max_shard_n: int = 1024, max_dense_gib: float = 8.0,
                 backend: str | None = None):
        self._graphs: dict[str, GraphData] = {}
        self._models: dict[str, _ModelEntry] = {}
        self._store = runtime.GraphStore(max_entries=max_graph_entries)
        # compiled (model, graph) units; each owns the full-graph softmax
        # that warm requests gather from
        self._executables: dict[tuple[str, str], runtime.Executable] = {}
        self._pending: list[NodeRequest] = []
        self.max_shard_n = max_shard_n
        self.max_dense_gib = max_dense_gib
        self.backend = backend
        self._stats = {
            "logits_cache_hits": 0, "logits_cache_misses": 0,
            "requests": 0, "batches": 0, "nodes_served": 0,
            "compiles": 0,
        }

    @property
    def stats(self) -> dict:
        """Serving counters merged with the runtime graph-store counters
        (kept under the historical key names)."""
        s = self._store.stats
        return {**self._stats,
                "graph_cache_hits": s["hits"],
                "graph_cache_misses": s["misses"],
                "graph_cache_evictions": s["evictions"]}

    # -- registration ------------------------------------------------------

    def register_graph(self, name: str, data: GraphData) -> None:
        # fail fast before sharding: densified shard blocks cost
        # (padded N)² · 4 bytes, which for e.g. full-scale reddit is ~200 TiB
        n_pad = -(-data.profile.num_nodes // self.max_shard_n) * self.max_shard_n
        est_bytes = n_pad ** 2 * 4
        if est_bytes > self.max_dense_gib * 2 ** 30:
            raise ValueError(
                f"graph {name!r} ({data.profile.num_nodes} nodes) would "
                f"densify to ~{est_bytes / 2**30:.0f} GiB of shard blocks "
                f"(limit {self.max_dense_gib} GiB); register a scaled-down "
                f"dataset (make_dataset(..., scale=...)) or raise "
                f"max_dense_gib")
        self._graphs[name] = data
        # stale sharded tensors / executables for a replaced graph must go
        self._store.evict(name)
        for key in [k for k in self._executables if k[1] == name]:
            del self._executables[key]

    def register_model(self, name: str, spec: ZooSpec,
                       params: dict | None = None, *, seed: int = 0) -> None:
        if params is None:
            import jax
            params = init_zoo(jax.random.key(seed), spec)
        self._models[name] = _ModelEntry(spec=spec, params=params)
        # a (re-)registered model invalidates its compiled units wholesale:
        # the spec (and thus plan/graph signature) may have changed
        for key in [k for k in self._executables if k[0] == name]:
            del self._executables[key]

    def invalidate(self, *, model: str | None = None,
                   graph: str | None = None) -> None:
        """Drop cached logits (e.g. after a parameter update)."""
        for (m, g), exe in self._executables.items():
            if (model is None or m == model) and (graph is None or g == graph):
                exe.invalidate()

    # -- compile path ------------------------------------------------------

    def executable(self, model: str, graph: str) -> runtime.Executable:
        """Fetch-or-compile the Executable serving a (model, graph) pair."""
        key = (model, graph)
        exe = self._executables.get(key)
        if exe is None:
            ent = self._models[model]
            exe = runtime.compile(
                ent.spec, self._graphs[graph], params=ent.params,
                backend=self.backend, max_shard_n=self.max_shard_n,
                store=self._store, graph_key=graph)
            self._executables[key] = exe
            self._stats["compiles"] += 1
        return exe

    def model_plan(self, model: str, graph: str) -> ModelPlan:
        """The layer-execution plan a (model, graph) pair is compiled with."""
        return self.executable(model, graph).plan

    # -- request path ------------------------------------------------------

    def submit(self, req: NodeRequest) -> None:
        self._pending.append(req)

    def flush(self) -> list[Prediction]:
        """Serve all pending requests, micro-batched by (model, graph).

        The queue is cleared only on success: a rejected batch (unknown
        name, bad node ids) leaves every request queued for the caller to
        repair or drop."""
        preds = self.serve(self._pending)
        self._pending = []
        return preds

    def serve(self, requests: Sequence[NodeRequest]) -> list[Prediction]:
        """Serve a batch; answers keep the caller's request order."""
        # validate everything before touching caches/stats so a bad request
        # rejects the batch atomically instead of half-serving it
        groups: OrderedDict[tuple[str, str], list[int]] = OrderedDict()
        for i, r in enumerate(requests):
            if r.model not in self._models:
                raise KeyError(f"unknown model {r.model!r}")
            if r.graph not in self._graphs:
                raise KeyError(f"unknown graph {r.graph!r}")
            ids = np.asarray(r.node_ids, dtype=np.int64)
            n_nodes = self._graphs[r.graph].profile.num_nodes
            if ids.size and (ids.min() < 0 or ids.max() >= n_nodes):
                raise IndexError(f"node ids out of range for graph "
                                 f"{r.graph!r} ({n_nodes} nodes)")
            groups.setdefault((r.model, r.graph), []).append(i)

        out: list[Prediction | None] = [None] * len(requests)
        for (model, graph), idxs in groups.items():
            t0 = time.perf_counter()
            exe = self.executable(model, graph)
            # one cache touch per request: the group's first touch may
            # compute the full-graph softmax, the rest count as hits
            for _ in idxs:
                hit = exe.has_cached_probs
                self._stats["logits_cache_hits" if hit
                            else "logits_cache_misses"] += 1
                probs = exe.full_probs()
            ms = (time.perf_counter() - t0) * 1e3
            self._stats["batches"] += 1
            for i in idxs:
                ids = np.asarray(requests[i].node_ids, dtype=np.int64)
                p = probs[ids]
                out[i] = Prediction(
                    graph=graph, model=model, node_ids=ids,
                    classes=np.argmax(p, axis=-1).astype(np.int32),
                    probs=np.max(p, axis=-1).astype(np.float32),
                    latency_ms=ms)
                self._stats["requests"] += 1
                self._stats["nodes_served"] += int(ids.size)
        return out  # type: ignore[return-value]

    def cache_report(self) -> str:
        s = self.stats
        g_tot = s["graph_cache_hits"] + s["graph_cache_misses"]
        l_tot = s["logits_cache_hits"] + s["logits_cache_misses"]
        return (f"graph-tensor cache: {s['graph_cache_hits']}/{g_tot} hits "
                f"({len(self._store)} resident, "
                f"{s['graph_cache_evictions']} evicted) | "
                f"logits cache: {s['logits_cache_hits']}/{l_tot} hits | "
                f"{s['compiles']} executables compiled | "
                f"{s['requests']} requests, {s['nodes_served']} nodes in "
                f"{s['batches']} batches")

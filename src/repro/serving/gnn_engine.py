"""Batched GNN node-classification serving (GNNIE-style graph caching).

Requests name a registered graph + model and a set of node ids; the engine
groups pending requests by (model, graph) into micro-batches and answers
each batch from a two-level cache:

  * **graph-tensor cache** — the expensive artifact is the sharded,
    normalization-baked ``GraphTensors`` (+ shard-grouped features). It is
    keyed on ``(graph, normalize, self_loops, shard_n)`` — the exact
    signature :func:`repro.gnn.models.graph_signature` assigns each
    architecture — so every model needing the same signature shares one
    entry. LRU-evicted at a configurable capacity.
  * **logits cache** — full-graph inference is the natural unit on an
    accelerator (one shard-grid sweep per layer covers every node), so the
    first request against a (model, graph) pair computes class
    probabilities for ALL nodes once; every later node id on that pair is
    a pure gather from the cached array. Invalidate with
    :meth:`GNNServeEngine.invalidate` after a weight swap.

Layer execution is planned per (model, graph) by ``repro.gnn.executor`` —
block size B, traversal order and fused/two-stage per layer from the
Table-I cost model, shard size from the on-chip budget.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import GraphTensors
from repro.gnn.executor import ModelPlan, plan_model
from repro.gnn.models import (ZooSpec, build_zoo_graph, graph_signature,
                              init_zoo, zoo_forward)
from repro.graphs.datasets import GraphData


@dataclasses.dataclass
class NodeRequest:
    """Classify ``node_ids`` of ``graph`` with ``model``."""

    graph: str
    node_ids: np.ndarray            # (k,) int
    model: str = "gcn"


@dataclasses.dataclass
class Prediction:
    graph: str
    model: str
    node_ids: np.ndarray
    classes: np.ndarray             # (k,) int32 argmax class per node
    probs: np.ndarray               # (k,) float32 softmax mass of the argmax
    latency_ms: float               # engine time for the micro-batch


@dataclasses.dataclass
class _GraphEntry:
    gt: GraphTensors
    h_grouped: jax.Array            # (S, n, F) shard-grouped features
    built_ms: float


@dataclasses.dataclass
class _ModelEntry:
    spec: ZooSpec
    params: dict
    plans: dict[str, ModelPlan] = dataclasses.field(default_factory=dict)


class GNNServeEngine:
    """Batched node-classification inference over named graphs/models."""

    def __init__(self, *, max_graph_entries: int = 8,
                 max_shard_n: int = 1024, max_dense_gib: float = 8.0):
        self._graphs: dict[str, GraphData] = {}
        self._models: dict[str, _ModelEntry] = {}
        self._graph_cache: OrderedDict[tuple, _GraphEntry] = OrderedDict()
        # full-graph class probabilities per (model, graph): softmax is
        # applied once at insert so warm requests only pay a gather
        self._logits_cache: dict[tuple[str, str], np.ndarray] = {}
        self._pending: list[NodeRequest] = []
        self.max_graph_entries = max_graph_entries
        self.max_shard_n = max_shard_n
        self.max_dense_gib = max_dense_gib
        self.stats = {
            "graph_cache_hits": 0, "graph_cache_misses": 0,
            "graph_cache_evictions": 0,
            "logits_cache_hits": 0, "logits_cache_misses": 0,
            "requests": 0, "batches": 0, "nodes_served": 0,
        }

    # -- registration ------------------------------------------------------

    def register_graph(self, name: str, data: GraphData) -> None:
        # fail fast before sharding: densified shard blocks cost
        # (padded N)² · 4 bytes, which for e.g. full-scale reddit is ~200 TiB
        n_pad = -(-data.profile.num_nodes // self.max_shard_n) * self.max_shard_n
        est_bytes = n_pad ** 2 * 4
        if est_bytes > self.max_dense_gib * 2 ** 30:
            raise ValueError(
                f"graph {name!r} ({data.profile.num_nodes} nodes) would "
                f"densify to ~{est_bytes / 2**30:.0f} GiB of shard blocks "
                f"(limit {self.max_dense_gib} GiB); register a scaled-down "
                f"dataset (make_dataset(..., scale=...)) or raise "
                f"max_dense_gib")
        self._graphs[name] = data
        # stale sharded tensors / logits for a replaced graph must go
        self._evict_graph(name)

    def register_model(self, name: str, spec: ZooSpec,
                       params: dict | None = None, *, seed: int = 0) -> None:
        if params is None:
            params = init_zoo(jax.random.key(seed), spec)
        self._models[name] = _ModelEntry(spec=spec, params=params)
        self.invalidate(model=name)

    def invalidate(self, *, model: str | None = None,
                   graph: str | None = None) -> None:
        """Drop cached logits (e.g. after a parameter update)."""
        keep = {}
        for (m, g), v in self._logits_cache.items():
            if (model is None or m == model) and (graph is None or g == graph):
                continue
            keep[(m, g)] = v
        self._logits_cache = keep

    def _evict_graph(self, name: str) -> None:
        for key in [k for k in self._graph_cache if k[0] == name]:
            del self._graph_cache[key]
        for ent in self._models.values():   # plans were shaped by the old graph
            ent.plans.pop(name, None)
        self.invalidate(graph=name)

    # -- graph-tensor cache ------------------------------------------------

    def _graph_entry(self, graph: str, arch: str, shard_n: int) -> _GraphEntry:
        norm, loops = graph_signature(arch)
        key = (graph, norm, loops, shard_n)
        if key in self._graph_cache:
            self.stats["graph_cache_hits"] += 1
            self._graph_cache.move_to_end(key)
            return self._graph_cache[key]
        self.stats["graph_cache_misses"] += 1
        data = self._graphs[graph]
        t0 = time.perf_counter()
        gt = build_zoo_graph(data.edges, data.profile.num_nodes, shard_n, arch)
        entry = _GraphEntry(gt=gt, h_grouped=gt.group(jnp.asarray(data.features)),
                            built_ms=(time.perf_counter() - t0) * 1e3)
        self._graph_cache[key] = entry
        while len(self._graph_cache) > self.max_graph_entries:
            self._graph_cache.popitem(last=False)
            self.stats["graph_cache_evictions"] += 1
        return entry

    # -- inference ---------------------------------------------------------

    def model_plan(self, model: str, graph: str) -> ModelPlan:
        """Lazily plan (and memoize) a model's layer execution for a graph."""
        ent = self._models[model]
        if graph not in ent.plans:
            data = self._graphs[graph]
            ent.plans[graph] = plan_model(
                ent.spec, data.profile.num_nodes, data.edges.shape[0],
                max_n=self.max_shard_n)
        return ent.plans[graph]

    def _full_graph_probs(self, model: str, graph: str) -> np.ndarray:
        key = (model, graph)
        if key in self._logits_cache:
            self.stats["logits_cache_hits"] += 1
            return self._logits_cache[key]
        self.stats["logits_cache_misses"] += 1
        ent = self._models[model]
        plan = self.model_plan(model, graph)
        gentry = self._graph_entry(graph, ent.spec.arch, plan.shard_n)
        logits = zoo_forward(ent.spec, ent.params, gentry.gt,
                             gentry.h_grouped, plans=plan.layers)
        probs = _softmax(np.asarray(jax.device_get(logits), dtype=np.float32))
        self._logits_cache[key] = probs
        return probs

    # -- request path ------------------------------------------------------

    def submit(self, req: NodeRequest) -> None:
        self._pending.append(req)

    def flush(self) -> list[Prediction]:
        """Serve all pending requests, micro-batched by (model, graph).

        The queue is cleared only on success: a rejected batch (unknown
        name, bad node ids) leaves every request queued for the caller to
        repair or drop."""
        preds = self.serve(self._pending)
        self._pending = []
        return preds

    def serve(self, requests: Sequence[NodeRequest]) -> list[Prediction]:
        """Serve a batch; answers keep the caller's request order."""
        # validate everything before touching caches/stats so a bad request
        # rejects the batch atomically instead of half-serving it
        groups: OrderedDict[tuple[str, str], list[int]] = OrderedDict()
        for i, r in enumerate(requests):
            if r.model not in self._models:
                raise KeyError(f"unknown model {r.model!r}")
            if r.graph not in self._graphs:
                raise KeyError(f"unknown graph {r.graph!r}")
            ids = np.asarray(r.node_ids, dtype=np.int64)
            n_nodes = self._graphs[r.graph].profile.num_nodes
            if ids.size and (ids.min() < 0 or ids.max() >= n_nodes):
                raise IndexError(f"node ids out of range for graph "
                                 f"{r.graph!r} ({n_nodes} nodes)")
            groups.setdefault((r.model, r.graph), []).append(i)

        out: list[Prediction | None] = [None] * len(requests)
        for (model, graph), idxs in groups.items():
            t0 = time.perf_counter()
            # one cache touch per request: the group's first touch may
            # compute full-graph probabilities, the rest count as hits
            for _ in idxs:
                probs = self._full_graph_probs(model, graph)
            ms = (time.perf_counter() - t0) * 1e3
            self.stats["batches"] += 1
            for i in idxs:
                ids = np.asarray(requests[i].node_ids, dtype=np.int64)
                p = probs[ids]
                out[i] = Prediction(
                    graph=graph, model=model, node_ids=ids,
                    classes=np.argmax(p, axis=-1).astype(np.int32),
                    probs=np.max(p, axis=-1).astype(np.float32),
                    latency_ms=ms)
                self.stats["requests"] += 1
                self.stats["nodes_served"] += int(ids.size)
        return out  # type: ignore[return-value]

    def cache_report(self) -> str:
        s = self.stats
        g_tot = s["graph_cache_hits"] + s["graph_cache_misses"]
        l_tot = s["logits_cache_hits"] + s["logits_cache_misses"]
        return (f"graph-tensor cache: {s['graph_cache_hits']}/{g_tot} hits "
                f"({len(self._graph_cache)} resident, "
                f"{s['graph_cache_evictions']} evicted) | "
                f"logits cache: {s['logits_cache_hits']}/{l_tot} hits | "
                f"{s['requests']} requests, {s['nodes_served']} nodes in "
                f"{s['batches']} batches")


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)

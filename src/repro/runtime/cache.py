"""Signature-keyed GraphTensors store (GNNIE-style graph-specific caching).

The expensive compile-time artifact is the sharded, normalization-baked
:class:`~repro.core.engines.GraphTensors` (+ shard-grouped features). One
store entry is keyed on ``(graph_key, normalize, self_loops, shard_n)`` —
exactly the signature :func:`repro.gnn.models.graph_signature` assigns each
architecture — so every Executable whose model needs the same signature
shares one build. Entries are LRU-evicted at a configurable capacity.

``runtime.compile`` uses a module-default store; the serving engine owns a
private one so its capacity and stats are isolated per engine instance.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import GraphTensors
from repro.gnn.models import graph_signature


@dataclasses.dataclass
class GraphEntry:
    gt: GraphTensors
    h_grouped: jax.Array | None     # (S, n, F) shard-grouped features
    built_ms: float


class GraphStore:
    """LRU cache of sharded graph builds, keyed by normalization signature."""

    def __init__(self, max_entries: int = 8):
        self._entries: OrderedDict[tuple, GraphEntry] = OrderedDict()
        self.max_entries = max_entries
        # built_ms_total makes rebuild churn visible: entries evicted under
        # use are rebuilt on the next miss, and only this counter shows it
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "built_ms_total": 0.0}

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, graph_key, edges: np.ndarray, num_nodes: int,
            shard_n: int, arch: str,
            features: np.ndarray | None = None) -> GraphEntry:
        """Fetch-or-build the GraphTensors for ``arch``'s signature.

        ``graph_key`` identifies the graph *contents* (the serving engine
        uses its registered name; standalone compiles use a fingerprint).
        Features are grouped once and cached alongside; an entry built
        featureless is upgraded in place on the first featureful request.
        """
        from repro.runtime.forward import build_graph_tensors

        norm, loops = graph_signature(arch)
        key = (graph_key, norm, loops, shard_n)
        entry = self._entries.get(key)
        if entry is not None:
            self.stats["hits"] += 1
            self._entries.move_to_end(key)
            if entry.h_grouped is None and features is not None:
                entry.h_grouped = entry.gt.group(jnp.asarray(features))
            return entry
        self.stats["misses"] += 1
        t0 = time.perf_counter()
        gt = build_graph_tensors(edges, num_nodes, shard_n, arch)
        h = gt.group(jnp.asarray(features)) if features is not None else None
        entry = GraphEntry(gt=gt, h_grouped=h,
                           built_ms=(time.perf_counter() - t0) * 1e3)
        self.stats["built_ms_total"] += entry.built_ms
        self._entries[key] = entry
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.stats["evictions"] += 1
        return entry

    def evict(self, graph_key=None) -> None:
        """Drop entries for one graph_key, or everything when None."""
        if graph_key is None:
            self._entries.clear()
            return
        for key in [k for k in self._entries if k[0] == graph_key]:
            del self._entries[key]


# module-default store shared by standalone runtime.compile() calls
_DEFAULT_STORE = GraphStore()


def default_store() -> GraphStore:
    return _DEFAULT_STORE

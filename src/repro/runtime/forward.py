"""Zoo-model forward pass on the GNNerator engines (runtime internals).

This is the single implementation behind :meth:`Executable.forward` and the
deprecated ``repro.gnn.models.zoo_forward`` shim. Per layer, an
executor-provided :class:`repro.gnn.executor.LayerPlan` picks the feature
block size B and whether the two stages run fused (h_agg never leaves
VMEM) or two-stage through feature memory; the kernel backend is threaded
explicitly so a compiled Executable is pinned to one backend regardless of
later env changes.

The GAT attention weights are computed per shard pair as an (S, S, n, n)
head-block tensor and fed straight to the shard-grid SpMM kernel — the
aggregation stays on the Graph Engine; only the masked softmax runs on the
activation unit (plain jnp here).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import (DenseEngine, GNNeratorController, GraphEngine,
                                GraphTensors)
from repro.core.sharding import shard_graph
from repro.gnn.models import ZooSpec, graph_signature
from repro.kernels.registry import KernelBackend


def build_graph_tensors(edges: np.ndarray, num_nodes: int, n: int,
                        arch: str) -> GraphTensors:
    """Shard + normalize a graph for the given zoo architecture."""
    norm, loops = graph_signature(arch)
    sg = shard_graph(edges, num_nodes, n, normalize=norm,
                     add_self_loops=loops)
    return GraphTensors.from_sharded(sg)


def layer_activation(spec: ZooSpec, i: int) -> str:
    """Activation for layer i: relu between layers, logits at the end.
    Shared with the sharded forward (dist/gnn.py) so the two execution
    paths can never disagree on where nonlinearities sit."""
    return "relu" if i < len(spec.layer_dims) - 1 else "none"


def _controller(plan, backend: KernelBackend | None) -> GNNeratorController:
    b = plan.B if plan is not None else 128
    fused = plan.fused if plan is not None else True
    return GNNeratorController(dense=DenseEngine(backend=backend),
                               graph=GraphEngine(block_b=b, backend=backend),
                               fuse=fused)


def _gat_attention_blocks(gt: GraphTensors, z_head: jax.Array,
                          s_src: jax.Array, s_dst: jax.Array,
                          negative_slope: float) -> jax.Array:
    """Per-head attention weights laid out on the shard grid.

    z_head: (S, n, F) head features; s_src/s_dst: (S, n) attention scores.
    Returns α as (S, S, n, n) blocks [dst_shard, src_shard, v, u] ready for
    the shard-grid SpMM kernel.
    """
    mask = gt.blocks != 0                                   # (S, S, n, n)
    logits = s_dst[:, None, :, None] + s_src[None, :, None, :]
    logits = jax.nn.leaky_relu(logits, negative_slope)
    logits = jnp.where(mask, logits, -jnp.inf)
    # masked softmax over ALL of v's in-neighbors: axes (src_shard, u)
    m = jnp.max(logits, axis=(1, 3), keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(mask, jnp.exp(logits - m), 0.0)
    denom = jnp.sum(e, axis=(1, 3), keepdims=True)
    return jnp.where(denom > 0, e / jnp.maximum(denom, 1e-30), 0.0)


def _gat_layer(spec: ZooSpec, layer: dict, gt: GraphTensors, h: jax.Array,
               ctrl: GNNeratorController, *, activation: str) -> jax.Array:
    s, n, din = h.shape
    heads, hd = layer["a_src"].shape
    z = ctrl.dense(h.reshape(s * n, din), layer["w"])       # (S·n, H·hd)
    z = z.reshape(s, n, heads, hd)
    s_src = jnp.einsum("snhf,hf->snh", z.astype(jnp.float32),
                       layer["a_src"].astype(jnp.float32))
    s_dst = jnp.einsum("snhf,hf->snh", z.astype(jnp.float32),
                       layer["a_dst"].astype(jnp.float32))
    outs = []
    for hix in range(heads):   # heads stay sequential: one α grid in VMEM
        alpha = _gat_attention_blocks(gt, z[..., hix, :],
                                      s_src[..., hix], s_dst[..., hix],
                                      spec.negative_slope)
        outs.append(ctrl.graph.spmm(alpha, z[..., hix, :]))
    out = jnp.concatenate(outs, axis=-1)                    # (S, n, H·hd)
    if activation == "relu":
        out = jax.nn.relu(out)
    return out


def forward(spec: ZooSpec, params: dict, gt: GraphTensors,
            h: jax.Array, *, plans: Sequence | None = None,
            backend: KernelBackend | None = None) -> jax.Array:
    """Run the model; h is (S, n, in_dim) shard-grouped (GraphTensors.group).

    ``plans`` is an optional per-layer sequence of LayerPlans from
    repro.gnn.executor; None falls back to the default controller (fused
    where legal, B=128). ``backend=None`` resolves per call from the
    kernel registry (env-var selectable).
    """
    for i, layer in enumerate(params["layers"]):
        plan = plans[i] if plans is not None else None
        ctrl = _controller(plan, backend)
        act = layer_activation(spec, i)
        if spec.arch == "gcn":
            h = ctrl.graph_first(gt, h, layer["w"], activation=act)
        elif spec.arch == "sage_mean":
            agg = ctrl.graph.aggregate(gt, h, op="linear")  # mean-normalized
            s, n, d = h.shape
            cat = jnp.concatenate([agg, h], axis=-1).reshape(s * n, 2 * d)
            h = ctrl.dense(cat, layer["w"], activation=act).reshape(s, n, -1)
        elif spec.arch == "sage_max":
            s, n, d = h.shape
            z = ctrl.dense(h.reshape(s * n, d), layer["w_pool"],
                           layer["b_pool"], activation="relu")
            zbar = ctrl.graph.aggregate(gt, z.reshape(s, n, d), op="max")
            cat = jnp.concatenate([zbar, h], axis=-1).reshape(s * n, 2 * d)
            h = ctrl.dense(cat, layer["w"], activation=act).reshape(s, n, -1)
        elif spec.arch == "gin":
            agg = ctrl.graph.aggregate(gt, h, op="linear")  # Σ, no self loop
            x = (1.0 + layer["eps"]) * h + agg
            s, n, d = x.shape
            hid = ctrl.dense(x.reshape(s * n, d), layer["w1"], layer["b1"],
                             activation="relu")
            h = ctrl.dense(hid, layer["w2"], layer["b2"],
                           activation=act).reshape(s, n, -1)
        elif spec.arch == "gat":
            h = _gat_layer(spec, layer, gt, h, ctrl, activation=act)
    return gt.ungroup(h)

"""`runtime.compile(spec, graph) -> Executable` — the one public entry.

The compile step is where the GNNerator Controller's planning lives: the
Table-I cost model picks (B, n, S, order, fused) per layer, the graph is
sharded + normalization-baked once per signature (shared via the
GraphStore), parameters are initialized (or adopted), and the forward is
jitted against one pinned kernel backend. Everything downstream — serving,
examples, benchmarks — holds an Executable instead of hand-chaining
planner/shard/init/forward.
"""
from __future__ import annotations

import hashlib
import os

import jax
import numpy as np

from repro.core.perf_model import GNNERATOR, Platform
from repro.gnn.executor import plan_model
from repro.gnn.models import ZooSpec, init_zoo
from repro.kernels import registry
from repro.runtime.cache import GraphStore, default_store
from repro.runtime.executable import Executable


def graph_fingerprint(edges: np.ndarray, num_nodes: int,
                      features: np.ndarray | None = None) -> str:
    """Cheap content key for an unnamed graph: shape/dtype plus a strided
    sample of the edge list AND the feature matrix (hashing all of
    reddit's ~115M edges per compile would dominate compile time).
    Features participate because the GraphStore caches the shard-grouped
    feature tensor under this key — same topology + different features
    must not collide."""
    h = hashlib.sha1()
    edges = np.ascontiguousarray(edges)
    step = max(1, edges.shape[0] // 1024)
    h.update(str((edges.shape, str(edges.dtype), num_nodes)).encode())
    h.update(edges[::step].tobytes())
    if features is not None:
        feats = np.ascontiguousarray(features)
        fstep = max(1, feats.shape[0] // 256)
        h.update(str((feats.shape, str(feats.dtype))).encode())
        h.update(feats[::fstep].tobytes())
    return h.hexdigest()


def _as_graph(graph):
    """Accept a GraphData, or (edges, num_nodes[, features])."""
    if hasattr(graph, "edges") and hasattr(graph, "profile"):
        return graph.edges, graph.profile.num_nodes, graph.features
    if isinstance(graph, (tuple, list)):
        if len(graph) == 2:
            edges, num_nodes = graph
            return np.asarray(edges), int(num_nodes), None
        edges, num_nodes, features = graph
        return np.asarray(edges), int(num_nodes), features
    raise TypeError(
        f"graph must be a GraphData or (edges, num_nodes[, features]) "
        f"tuple, got {type(graph).__name__}")


def compile(spec: ZooSpec, graph, *,
            platform: Platform = GNNERATOR,
            backend: str | registry.KernelBackend | None = None,
            op_backends: dict | None = None,
            params: dict | None = None,
            seed: int = 0,
            max_shard_n: int = 1024,
            block_candidates: tuple[int, ...] | None = None,
            store: GraphStore | None = None,
            graph_key=None,
            mesh=None,
            donate_features: bool = False,
            plan: str = "analytic",
            tune_budget: int = 16,
            tune_seed: int = 0,
            tune_reps: int = 3,
            tune_warmup: int = 1,
            tune_timeout_s: float | None = 30.0,
            plan_cache_dir=None,
            analyze: str | None = None) -> Executable:
    """Plan, shard, initialize and jit one zoo model for one graph.

    Args:
      spec: the :class:`~repro.gnn.models.ZooSpec` to compile.
      graph: a :class:`~repro.graphs.datasets.GraphData` or an
        ``(edges, num_nodes[, features])`` tuple.
      platform: the performance-model platform the planner optimizes for.
      mesh: a ``(data, model)`` jax mesh (``launch.mesh.make_mesh_for``);
        when given the returned Executable is a
        :class:`repro.dist.gnn.ShardedExecutable` whose forward runs
        under ``shard_map`` — data axis = contiguous dst-shard row
        groups, model axis = feature blocks.
      backend: kernel backend name/object; None resolves from the
        ``REPRO_KERNEL_BACKEND`` env var (default ``pallas``) and is then
        *pinned* into the Executable.
      op_backends: optional per-op overrides, e.g.
        ``{"gather_aggregate": "jax"}`` — merged over ``backend``.
      params: adopt an existing param pytree; None initializes from seed.
      max_shard_n: planner cap on nodes per shard.
      store: GraphStore for the signature-keyed GraphTensors build
        (default: the module-wide store, so repeat compiles share builds).
      graph_key: cache key naming the graph contents (default: a
        fingerprint of the edge list).
      donate_features: jit the features-passed forward path with the input
        buffer donated.
      plan: plan source — ``"analytic"`` trusts the Table-I cost model;
        ``"autotune"`` measures the analytic top-k candidates on the
        resolved backend (:func:`repro.tune.autotune_plan`) and compiles
        the measured winner, memoized through the plan cache under an
        environment-scoped key.
      tune_budget / tune_seed / tune_reps / tune_warmup / tune_timeout_s:
        autotuner knobs (max candidates measured; memo-key seed;
        median-of-k reps; warm-up runs; per-candidate timeout). Ignored
        for ``plan="analytic"``.
      plan_cache_dir: persist/load plans (and autotuned winners) as JSON
        (default: env ``REPRO_PLAN_CACHE``).
      analyze: run the compile-time static-analysis passes
        (:func:`repro.analyze.analyze_executable` — retrace, dtype, plan
        legality, comm contract on a mesh) over the compiled result.
        ``None``/``"off"`` skips; ``"warn"`` attaches the report as
        ``exe.analysis`` and emits a ``UserWarning`` for warning-or-worse
        findings; ``"error"`` additionally raises
        :class:`repro.analyze.AnalysisError` on any error finding.
    """
    if plan not in ("analytic", "autotune"):
        raise ValueError(f"plan must be 'analytic' or 'autotune', "
                         f"got {plan!r}")
    if analyze not in (None, "off", "warn", "error"):
        raise ValueError(f"analyze must be None, 'off', 'warn' or "
                         f"'error', got {analyze!r}")
    edges, num_nodes, features = _as_graph(graph)
    # precedence per op: explicit op_backends > explicit backend arg >
    # REPRO_KERNEL_BACKEND_<OP> env > global env > default. An explicit
    # backend arg deliberately beats the per-op env vars; when none is
    # given, the env overrides must survive into the pinned Executable.
    per_op = dict(op_backends or {})
    if backend is None:
        for op in registry.OP_NAMES:
            env = os.environ.get(f"REPRO_KERNEL_BACKEND_{op.upper()}")
            if env and op not in per_op:
                per_op[op] = env
    be = registry.resolve(None, backend)
    if per_op:
        be = registry.composite_backend(be, per_op)

    if graph_key is None:
        graph_key = graph_fingerprint(edges, num_nodes, features)
    # explicit None check: GraphStore has __len__, so an empty store is falsy
    the_store = default_store() if store is None else store

    if params is None:
        params = init_zoo(jax.random.key(seed), spec)

    plan_kwargs = dict(platform=platform, max_n=max_shard_n,
                       cache_dir=plan_cache_dir)
    if block_candidates is not None:
        plan_kwargs["block_candidates"] = tuple(block_candidates)

    plan_source, tune_report = "analytic", None
    if plan == "autotune":
        if mesh is not None:
            raise ValueError(
                "plan='autotune' measures the single-device forward and "
                "cannot tune sharded (mesh=) execution yet; compile with "
                "plan='analytic' on a mesh")
        from repro import tune
        rec = tune.autotune_plan(
            spec, edges, num_nodes, backend=be, features=features,
            params=params, budget=tune_budget, seed=tune_seed,
            reps=tune_reps, warmup=tune_warmup, timeout_s=tune_timeout_s,
            cache_dir=plan_cache_dir, store=the_store, graph_key=graph_key,
            **{k: v for k, v in plan_kwargs.items() if k != "cache_dir"})
        mplan, plan_source, tune_report = rec.plan, rec.plan_source, \
            rec.report()
    else:
        mplan = plan_model(spec, num_nodes, int(edges.shape[0]),
                           **plan_kwargs)

    entry = the_store.get(graph_key, edges, num_nodes, mplan.shard_n,
                          spec.arch, features=features)

    kw = dict(spec=spec, plan=mplan, backend=be, gt=entry.gt,
              h_grouped=entry.h_grouped, params=params,
              graph_key=graph_key, donate_features=donate_features,
              plan_source=plan_source, tune_report=tune_report)
    if mesh is not None:
        from repro.dist.gnn import ShardedExecutable
        exe: Executable = ShardedExecutable(mesh=mesh, **kw)
    else:
        exe = Executable(**kw)

    if analyze in ("warn", "error"):
        from repro import analyze as _analyze
        report = _analyze.analyze_executable(exe)
        exe.analysis = report
        if analyze == "error" and report.failed("error"):
            raise _analyze.AnalysisError(report)
        if report.at_least("warning"):
            import warnings
            warnings.warn(f"static analysis of the compiled "
                          f"{spec.arch} executable:\n{report.render()}",
                          stacklevel=2)
    return exe

"""repro.runtime — the compile-style GNN execution API.

    from repro import runtime
    exe = runtime.compile(spec, graph, backend="reference")
    logits = exe.forward()                  # full graph
    classes, probs = exe.predict([0, 7, 9]) # node batch, cached softmax
    print(exe.summary())

One ``compile()`` call replaces the old hand-chained
``plan_model → build_zoo_graph → init_zoo → zoo_forward`` pipeline (those
remain as deprecation shims in :mod:`repro.gnn.models`). Kernel backends
(``pallas`` / ``jax`` / ``reference``) are pluggable per compile and per
op via :mod:`repro.kernels.registry`. Plans come from one of two
sources: the analytic Table-I cost model (``plan="analytic"``, default)
or the empirical autotuner (``plan="autotune"``, :mod:`repro.tune`) that
measures the analytic top-k on the real backend and memoizes the winner.
"""
from repro.gnn.executor import clear_plan_cache, plan_cache_stats
from repro.kernels.registry import (KernelBackend, get_backend,
                                    list_backends, register_backend)
from repro.runtime.api import compile, graph_fingerprint
from repro.runtime.cache import GraphStore, default_store
from repro.runtime.executable import Executable
from repro.runtime.fit import FitResult, TrainableExecutable, fit
from repro.runtime.forward import forward
from repro.tune import clear_tune_cache, tune_cache_stats

__all__ = [
    "compile", "fit", "Executable", "TrainableExecutable", "FitResult",
    "forward", "GraphStore", "default_store",
    "KernelBackend", "get_backend", "list_backends", "register_backend",
    "plan_cache_stats", "clear_plan_cache", "graph_fingerprint",
    "tune_cache_stats", "clear_tune_cache",
]

"""The compiled unit the runtime hands back: plan + graph + params + jit.

An :class:`Executable` owns everything needed to run one zoo model on one
graph on one kernel backend:

  * the :class:`~repro.gnn.executor.ModelPlan` (content-hash memoized by
    the planner),
  * the signature-keyed :class:`~repro.core.engines.GraphTensors` build
    (shared across Executables via the runtime GraphStore),
  * a jitted forward — full-graph (`forward`) and node-batch
    (`forward_nodes` / `predict`) entry points; the node-batch path is
    answered from a cached full-graph softmax, the natural unit of work on
    the accelerator (one shard-grid sweep per layer covers every node),
  * plan/param serialization (`save_plan`, `save_params`, `load_params`).

The kernel backend is pinned at compile time: later changes to the
``REPRO_KERNEL_BACKEND`` env var do not retroactively re-route a compiled
Executable.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import GraphTensors
from repro.gnn.executor import ModelPlan
from repro.gnn.models import ZooSpec
from repro.kernels.registry import KernelBackend
from repro.runtime import forward as _fwd


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def _flatten_params(tree, prefix="", out=None) -> dict:
    if out is None:
        out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            _flatten_params(v, f"{prefix}{k}/", out)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            _flatten_params(v, f"{prefix}{i}/", out)
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def validate_params_like(old, new) -> None:
    """Raise ValueError unless ``new`` has the same pytree structure and
    per-leaf shapes as ``old`` — the hot-reload contract (same shapes =>
    existing jit traces keep serving). Shared by
    :meth:`Executable.update_params` and the serving engine's
    all-or-nothing reload pre-check."""
    old_leaves, old_def = jax.tree_util.tree_flatten(old)
    new_leaves, new_def = jax.tree_util.tree_flatten(new)
    if old_def != new_def:
        raise ValueError(
            f"param tree mismatch: compiled {old_def}, got {new_def}")
    for i, (o, n) in enumerate(zip(old_leaves, new_leaves)):
        if jnp.shape(o) != jnp.shape(n):
            raise ValueError(
                f"param leaf {i} shape mismatch: compiled "
                f"{jnp.shape(o)}, got {jnp.shape(n)}")


def _unflatten_params(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        node = root
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)

    def listify(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.isdigit() for k in node):
            # index-robust: a pruned/partial checkpoint may hold
            # non-contiguous digit keys ("0", "2"); rebuild the list from
            # the keys actually present, in numeric order, instead of
            # assuming 0..len-1 (which KeyError'd on any gap)
            return [listify(node[k]) for k in sorted(node, key=int)]
        return {k: listify(v) for k, v in node.items()}

    return listify(root)


class Executable:
    """A zoo model compiled against one graph, plan and kernel backend."""

    def __init__(self, *, spec: ZooSpec, plan: ModelPlan,
                 backend: KernelBackend, gt: GraphTensors,
                 h_grouped: jax.Array | None, params: dict,
                 graph_key=None, donate_features: bool = False,
                 plan_source: str = "analytic",
                 tune_report: dict | None = None):
        self.spec = spec
        self.plan = plan
        self.backend = backend
        self.gt = gt
        self.params = params
        self.graph_key = graph_key
        # where the plan came from ("analytic" | "autotune" |
        # "analytic_fallback") and, for tuned plans, the measurement
        # evidence (winner vs analytic ms, candidates tried) — surfaced by
        # summary() so a serving operator can see WHY this config runs
        self.plan_source = plan_source
        self.tune_report = tune_report
        # static-analysis Report, populated by runtime.compile(analyze=...)
        self.analysis = None
        self._h_grouped = h_grouped
        self._probs: np.ndarray | None = None

        fwd = self._forward_fn()
        self._jit_forward = jax.jit(fwd)
        # the donated variant consumes the caller's fresh feature buffer so
        # XLA can reuse it for layer intermediates; only sound for features
        # passed per call (the cached buffer must survive repeat calls)
        self._jit_forward_donate = (
            jax.jit(fwd, donate_argnums=(1,)) if donate_features else None)
        # node-batch gather, jitted over PADDED id vectors: ids arrive
        # bucketed to a power of two (`_gather_bucket`), so arbitrary batch
        # sizes share O(log max_batch) traces instead of one per distinct
        # shape (the per-request dispatch-compile the retrace pass flags)
        self._jit_gather = jax.jit(lambda logits, ids: logits[ids])

    def _forward_fn(self):
        """(params, h_grouped) -> (N, C) logits — the function jitted at
        construction. Subclasses (dist.gnn.ShardedExecutable) override
        this to run the same plan under shard_map."""
        spec, plan, backend, gt = self.spec, self.plan, self.backend, self.gt

        def fwd(p, h):
            return _fwd.forward(spec, p, gt, h, plans=plan.layers,
                                backend=backend)

        return fwd

    # -- forward entry points ---------------------------------------------

    @property
    def backend_name(self) -> str:
        return self.backend.name

    def forward(self, params: dict | None = None,
                features: np.ndarray | jax.Array | None = None) -> jax.Array:
        """Full-graph logits (N, num_classes).

        ``features`` (N, F) overrides the compiled-in graph features (they
        are shard-grouped here); ``params`` overrides the compiled-in
        parameters — both stay differentiable/jit-stable, so this is also
        the training entry point.
        """
        p = self.params if params is None else params
        if features is None:
            if self._h_grouped is None:
                raise ValueError("compiled without features; pass features=")
            return self._jit_forward(p, self._h_grouped)
        h = self.gt.group(jnp.asarray(features))
        if self._jit_forward_donate is not None:
            return self._jit_forward_donate(p, h)
        return self._jit_forward(p, h)

    def _check_node_ids(self, node_ids) -> np.ndarray:
        """Validate ids against the compiled graph. Negative ids would
        silently wrap around (numpy/jnp indexing) and return the *wrong
        node's* prediction; ids >= N would clamp or wrap — both are data
        corruption, not errors, unless caught here."""
        ids = np.asarray(node_ids, dtype=np.int64)
        if ids.size:
            lo, hi = int(ids.min()), int(ids.max())
            if lo < 0 or hi >= self.gt.num_nodes:
                raise ValueError(
                    f"node ids must be in [0, {self.gt.num_nodes}); got "
                    f"range [{lo}, {hi}]")
        return ids

    @staticmethod
    def _gather_bucket(k: int) -> int:
        """Pad bucket for a node-batch gather: next power of two, floor 8,
        so every batch size in a bucket reuses one gather trace."""
        return max(8, 1 << max(k - 1, 0).bit_length())

    def forward_nodes(self, node_ids, params: dict | None = None) -> jax.Array:
        """Node-batch logits (k, num_classes) for ``node_ids``.

        Ids are padded to the enclosing power-of-two bucket before the
        jitted gather: without the bucket, every distinct batch size is a
        new gather shape and a new compile — the per-node-batch retrace
        hazard ``repro.analyze``'s retrace pass exists to catch.
        """
        ids = self._check_node_ids(node_ids)
        logits = self.forward(params)
        k = int(ids.size)
        if k == 0:
            return logits[:0]
        padded = np.zeros(self._gather_bucket(k), dtype=np.int32)
        padded[:k] = ids
        return self._jit_gather(logits, jnp.asarray(padded))[:k]

    def full_probs(self) -> np.ndarray:
        """Cached full-graph class probabilities (N, C); computed once per
        parameter set, then every node-batch request is a pure gather."""
        if self._probs is None:
            logits = self.forward()
            # the ONE deliberate materialization point: the softmax cache
            # lives on host so every later request is a numpy gather
            host = jax.device_get(logits)  # analyze: allow(host-sync)
            self._probs = _softmax(np.asarray(host, dtype=np.float32))
        return self._probs

    def predict(self, node_ids) -> tuple[np.ndarray, np.ndarray]:
        """(classes, probs) for a node batch, served from the cached
        full-graph softmax."""
        p = self.full_probs()[self._check_node_ids(node_ids)]
        return (np.argmax(p, axis=-1).astype(np.int32),
                np.max(p, axis=-1).astype(np.float32))

    def step(self, node_id_batches) -> list[tuple[np.ndarray, np.ndarray,
                                                  float]]:
        """Batch-step entry point (the serving Engine protocol's unit of
        work): answer a micro-batch of node-id queries from the cached
        full-graph softmax. Each query is timed individually — the
        full-graph forward runs at most once, on the first cold query,
        and is charged to the query that triggered it; warm queries pay
        only their gather. Returns ``(classes, probs, engine_ms)`` per
        query, positionally."""
        out = []
        for ids in node_id_batches:
            t0 = time.perf_counter()
            classes, probs = self.predict(ids)
            out.append((classes, probs, (time.perf_counter() - t0) * 1e3))
        return out

    @property
    def has_cached_probs(self) -> bool:
        return self._probs is not None

    def invalidate(self) -> None:
        """Drop the cached full-graph probabilities (e.g. weight swap)."""
        self._probs = None

    def set_params(self, params: dict) -> None:
        self.params = params
        self.invalidate()

    def update_params(self, params: dict) -> None:
        """Hot weight reload: adopt a new parameter pytree without
        recompiling. The tree structure and every leaf shape must match
        the compiled params — same shapes means the existing jit traces
        keep serving, so a reload costs one softmax recompute, not a
        compile. The cached full-graph probabilities are invalidated
        (exactly once) as part of the swap."""
        validate_params_like(self.params, params)
        self.set_params(params)

    # -- introspection / serialization ------------------------------------

    def summary(self) -> str:
        n_params = sum(int(np.prod(np.shape(x)))
                       for x in jax.tree_util.tree_leaves(self.params))
        head = (f"Executable[{self.spec.arch}] backend={self.backend.name} "
                f"plan={self.plan_source} params={n_params} "
                f"grid={self.gt.S}x{self.gt.S} n={self.gt.n}")
        lines = [head]
        r = self.tune_report
        if r is not None:
            if r.get("winner_ms") is not None:
                vs = (f"vs analytic {r['analytic_ms']:.3f} ms "
                      f"({r['speedup']:.2f}x, " if r.get("analytic_ms")
                      else "(analytic unmeasured, ")
                lines.append(
                    f"  autotune: winner {r['winner_ms']:.3f} ms "
                    f"{vs}{r['candidates_measured']} candidates, "
                    f"{r['candidates_failed']} failed, "
                    f"{r.get('candidates_pruned', 0)} pruned)")
            else:
                lines.append(
                    f"  autotune: analytic fallback "
                    f"({r['candidates_measured']} candidates, "
                    f"{r['candidates_failed']} failed, "
                    f"{r.get('candidates_pruned', 0)} pruned)")
        lines.append(self.plan.summary())
        return "\n".join(lines)

    def plan_json(self) -> dict:
        return self.plan.to_json()

    def save_plan(self, path) -> None:
        pathlib.Path(path).write_text(
            json.dumps(self.plan_json(), indent=2) + "\n")

    def save_params(self, path) -> None:
        np.savez(path, **_flatten_params(self.params))

    def load_params(self, path) -> dict:
        with np.load(path) as z:
            params = _unflatten_params(dict(z))
        self.set_params(params)
        return params

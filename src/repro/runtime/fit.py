"""End-to-end GNN training over compiled Executables (`runtime.fit`).

The same engine/kernel split the serving path exercises once per request —
dense feature extraction + sparse aggregation — is what a training step
exercises twice (forward and backward). Every kernel backend is
differentiable (the Pallas kernels carry oracle-derived ``custom_vjp``s,
the jax/reference backends are ad-traceable jnp), so training reuses the
exact compiled artifact serving runs on:

    result = runtime.fit(spec, graph, steps=200, backend="reference")
    result.executable.predict([0, 7, 9])     # serves the trained weights

:class:`TrainableExecutable` wraps one compiled
:class:`~repro.runtime.executable.Executable` (single-device or a
``mesh=`` :class:`~repro.dist.gnn.ShardedExecutable`) with a jitted
AdamW train step in two regimes:

  * **full-batch** — masked cross-entropy over the full-graph forward;
    on a mesh the gradient's data-parallel psum falls out of the
    ``shard_map`` transpose (all-gather -> reduce-scatter), measurable
    via :meth:`TrainableExecutable.train_comm_stats`.
  * **mini-batch** — a :class:`~repro.graphs.sampler.NeighborSampler`
    draws fixed-budget subgraphs; each is sharded to the same (S, n)
    grid and padded to one edge cap, so the step function traces once
    and every step reuses the jit.

The loop itself is :class:`~repro.training.train_loop.TrainLoop` — the
same fault-tolerant machinery LM training uses: periodic + preemption
checkpoints through :class:`~repro.checkpoint.manager.CheckpointManager`,
deterministic resume (the sampler is seeded by step), straggler logging.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import GraphTensors
from repro.core.sharding import shard_graph
from repro.gnn.models import ZooSpec, graph_signature
from repro.graphs.sampler import NeighborSampler, SubgraphBatch
from repro.runtime import forward as _fwd
from repro.runtime.executable import (Executable, _flatten_params,
                                      _unflatten_params)
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      make_schedule)


def masked_cross_entropy(logits: jax.Array, labels: jax.Array,
                         mask: jax.Array) -> jax.Array:
    """Mean CE over ``mask``-selected nodes (f32, mask-weighted)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def _masked_accuracy(logits, labels, mask):
    m = mask.astype(jnp.float32)
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return jnp.sum(hit * m) / jnp.maximum(jnp.sum(m), 1.0)


def _pad_axis(x: np.ndarray, size: int, axis: int) -> np.ndarray:
    pad = size - x.shape[axis]
    if pad < 0:
        raise ValueError(f"cannot pad axis {axis} of {x.shape} to {size}")
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


class TrainableExecutable:
    """A compiled Executable plus the jitted train step that updates it.

    Functional core (``step_fn(params, opt_state, batch)``), stateful
    shell (``run()`` threads params/opt_state through
    :class:`~repro.training.train_loop.TrainLoop` and leaves the trained
    weights hot-swapped into ``self.executable``).
    """

    def __init__(self, exe: Executable, labels: np.ndarray, *,
                 train_mask: np.ndarray | None = None,
                 features: np.ndarray | None = None,
                 opt_cfg: AdamWConfig | None = None,
                 sampler: NeighborSampler | None = None):
        if exe._h_grouped is None and features is None:
            raise ValueError("training needs features: compile with a "
                             "featureful graph or pass features=")
        self.executable = exe
        self.spec: ZooSpec = exe.spec
        self.opt_cfg = opt_cfg or AdamWConfig(
            lr=5e-3, weight_decay=0.0, grad_clip=0.0, schedule="constant",
            warmup_steps=0)
        self._schedule = make_schedule(self.opt_cfg)
        # the jitted step DONATES its params argument; train on a copy so
        # step 0 can never invalidate the Executable's own buffers (an
        # exception mid-fit would otherwise leave exe.params deleted and
        # the compiled unit unusable)
        self.params = jax.tree.map(jnp.array, exe.params)
        self.opt_state = adamw_init(self.params)
        self.sampler = sampler

        n = exe.gt.num_nodes
        labels = np.asarray(labels)
        if labels.shape[0] != n:
            raise ValueError(f"labels cover {labels.shape[0]} nodes, graph "
                             f"has {n}")
        self._labels = np.asarray(labels, dtype=np.int32)
        self._train_mask = (np.ones(n, dtype=bool) if train_mask is None
                            else np.asarray(train_mask, dtype=bool))
        self._features = features
        if sampler is None:
            h = exe._h_grouped if exe._h_grouped is not None \
                else exe.gt.group(jnp.asarray(features))
            self._full_batch = (h, jnp.asarray(self._labels),
                                jnp.asarray(self._train_mask))
            self._jit_step = jax.jit(self._make_full_step(),
                                     donate_argnums=(0, 1))
        else:
            if getattr(exe, "mesh", None) is not None:
                raise NotImplementedError(
                    "mini-batch training is single-device; mesh training "
                    "runs full-batch (the sampled subgraph is already the "
                    "parallelism unit)")
            if features is None:
                raise ValueError("mini-batch training needs raw features= "
                                 "(the compiled h_grouped covers the full "
                                 "graph, not sampled subgraphs)")
            self._features = np.asarray(features, dtype=np.float32)
            self._mb = self._make_minibatch_builder()
            self._jit_step = jax.jit(self._make_mini_step(),
                                     donate_argnums=(0, 1))

    # -- step construction -------------------------------------------------

    def _make_full_step(self) -> Callable:
        fwd = self.executable._forward_fn()
        opt_cfg, schedule = self.opt_cfg, self._schedule

        def step(params, opt_state, h, labels, mask):
            def loss_fn(p):
                logits = fwd(p, h)
                return masked_cross_entropy(logits, labels, mask), logits

            (loss, logits), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt_state, stats = adamw_update(
                grads, opt_state, params, opt_cfg, schedule)
            metrics = {"loss": loss,
                       "acc": _masked_accuracy(logits, labels, mask),
                       **stats}
            return params, opt_state, metrics

        return step

    def _make_minibatch_builder(self) -> Callable:
        """numpy side of the mini-batch path: sample -> shard -> pad to
        the fixed (S, n, E_cap) template so one jit trace serves every
        step."""
        from repro.gnn.executor import plan_model

        exe, smp = self.executable, self.sampler
        norm, loops = graph_signature(self.spec.arch)
        budget = smp.budget
        est_edges = min(smp.edge_cap, budget * max(smp.fanout))
        plan = plan_model(self.spec, budget, est_edges,
                          max_n=min(exe.gt.n, budget))
        self.minibatch_plan = plan
        n_sub = plan.shard_n
        s_sub = -(-budget // n_sub)
        # per-pair cap: dense block bound (+n for stacked self loops) vs
        # total-unique-edge bound (+budget for the self loops shard_graph
        # appends on every slot)
        e_cap = min(n_sub * n_sub + n_sub, smp.edge_cap + budget)
        self._mb_shape = (s_sub, n_sub, e_cap)

        def build(step: int):
            batch: SubgraphBatch = smp.sample(step)
            sg = shard_graph(batch.edges, budget, n_sub,
                             add_self_loops=loops, normalize=norm)
            feats = self._features[batch.nodes] * \
                batch.node_valid[:, None].astype(np.float32)
            h = _pad_axis(feats, s_sub * n_sub, 0).reshape(s_sub, n_sub, -1)
            labels = self._labels[batch.nodes]
            mask = batch.seed_mask & self._train_mask[batch.nodes]
            return (jnp.asarray(sg.blocks),
                    jnp.asarray(_pad_axis(sg.edge_src, e_cap, 2)),
                    jnp.asarray(_pad_axis(sg.edge_dst, e_cap, 2)),
                    jnp.asarray(_pad_axis(sg.edge_valid, e_cap, 2)),
                    jnp.asarray(h), jnp.asarray(labels), jnp.asarray(mask))

        return build

    def _make_mini_step(self) -> Callable:
        spec, backend = self.spec, self.executable.backend
        opt_cfg, schedule = self.opt_cfg, self._schedule
        budget = self.sampler.budget
        s_sub, n_sub, _ = self._mb_shape
        plans = self.minibatch_plan.layers

        def step(params, opt_state, blocks, e_src, e_dst, e_valid,
                 h, labels, mask):
            gt = GraphTensors(blocks=blocks, edge_src=e_src, edge_dst=e_dst,
                              edge_valid=e_valid, num_nodes=budget,
                              n=n_sub, S=s_sub)

            def loss_fn(p):
                logits = _fwd.forward(spec, p, gt, h, plans=plans,
                                      backend=backend)
                return masked_cross_entropy(logits, labels, mask), logits

            (loss, logits), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt_state, stats = adamw_update(
                grads, opt_state, params, opt_cfg, schedule)
            metrics = {"loss": loss,
                       "acc": _masked_accuracy(logits, labels, mask),
                       **stats}
            return params, opt_state, metrics

        return step

    # -- TrainLoop protocol ------------------------------------------------

    def data(self, step: int):
        """Step-indexable batch (deterministic => resume-safe)."""
        if self.sampler is None:
            return self._full_batch
        return self._mb(step)

    def step_fn(self, params, opt_state, batch):
        return self._jit_step(params, opt_state, *batch)

    def run(self, steps: int, *, ckpt_manager=None, ckpt_every: int = 50,
            log_every: int = 25,
            log: Callable[[str], None] = print) -> list:
        """Train to ``steps`` total (resuming from ``ckpt_manager`` if it
        holds a checkpoint), hot-swap the trained weights into the
        Executable, and return the ``(step, loss)`` history."""
        from repro.training.train_loop import TrainLoop

        loop = TrainLoop(cfg=None, opt_cfg=self.opt_cfg, data_iter=self.data,
                         ckpt_manager=ckpt_manager, ckpt_every=ckpt_every,
                         log_every=log_every)
        self.params, self.opt_state, history = loop.run(
            self.params, self.opt_state, steps, train_step=self.step_fn,
            log=log)
        if ckpt_manager is not None:
            ckpt_manager.wait()
        self.executable.update_params(self.params)
        return history

    # -- evaluation / state ------------------------------------------------

    def train_accuracy(self, params=None) -> float:
        """Full-graph accuracy over the train mask (current params)."""
        p = self.params if params is None else params
        logits = self.executable.forward(
            p, features=None if self._features is None
            or self.executable._h_grouped is not None else self._features)
        return float(_masked_accuracy(jnp.asarray(logits),
                                      jnp.asarray(self._labels),
                                      jnp.asarray(self._train_mask)))

    def state_dict(self) -> dict:
        """The resumable train state as one pytree."""
        return {"params": self.params, "opt": self.opt_state}

    def save_state(self, path) -> None:
        """npz snapshot of params + optimizer state (flat pytree keys —
        the same layout ``Executable.save_params`` uses)."""
        np.savez(path, **_flatten_params(self.state_dict()))

    def load_state(self, path) -> dict:
        with np.load(path) as z:
            state = _unflatten_params(dict(z))
        self.params = state["params"]
        opt = state["opt"]
        opt["step"] = jnp.asarray(opt["step"], jnp.int32)
        self.opt_state = opt
        self.executable.update_params(self.params)
        return state

    # -- distributed accounting --------------------------------------------

    def train_comm_stats(self) -> dict:
        """Collective traffic of the compiled TRAIN step (mesh runs only):
        per-kind wire bytes/counts from the HLO, next to the forward
        all-gather model — the backward pass adds the all-gather
        transposes (reduce-scatter) and the data-parallel gradient psum
        (all-reduce over replicated params)."""
        from repro.dist.hlo_analysis import analyze_collectives

        exe = self.executable
        if getattr(exe, "mesh", None) is None:
            raise ValueError("train_comm_stats needs a mesh-compiled "
                             "Executable (runtime.fit(..., mesh=...))")
        aval = lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                              jnp.result_type(x))
        args = (jax.tree.map(aval, self.params),
                jax.tree.map(aval, self.opt_state),
                *(aval(b) for b in self._full_batch))
        hlo = self._jit_step.lower(*args).compile().as_text()
        stats = analyze_collectives(hlo)
        return {
            "measured_wire_bytes": dict(stats.wire_bytes),
            "measured_counts": dict(stats.counts),
            "forward_allgather_wire_bytes":
                sum(exe._layer_allgather_bytes()),
            "n_data": exe.n_data,
            "n_model": exe.n_model,
        }

    def verify_train_comm(self) -> dict:
        """Assert the train step's measured collectives are consistent
        with the forward model: at least the forward all-gather volume on
        the wire, plus a reduction collective carrying the data-parallel
        gradient psum. Returns :meth:`train_comm_stats`."""
        cs = self.train_comm_stats()
        measured_ag = cs["measured_wire_bytes"].get("all-gather", 0.0)
        expected_fwd = cs["forward_allgather_wire_bytes"]
        assert measured_ag >= 0.98 * expected_fwd, (measured_ag, expected_fwd)
        if cs["n_data"] * cs["n_model"] > 1:
            reduces = sum(cs["measured_counts"].get(k, 0)
                          for k in ("all-reduce", "reduce-scatter"))
            assert reduces > 0, cs["measured_counts"]
        return cs


@dataclasses.dataclass
class FitResult:
    """What :func:`fit` hands back: the trained, servable Executable plus
    the functional train state and loss history."""

    executable: Executable
    trainable: TrainableExecutable
    params: dict
    opt_state: dict
    history: list          # (step, loss) at log_every cadence

    def train_accuracy(self) -> float:
        return self.trainable.train_accuracy()


def fit(spec: ZooSpec, graph, labels=None, *,
        train_mask=None, steps: int = 100,
        opt: AdamWConfig | None = None, lr: float = 5e-3,
        weight_decay: float = 0.0, grad_clip: float = 0.0,
        schedule: str = "constant", warmup_steps: int = 0,
        batch_nodes: int = 0, fanout: Sequence[int] = (10, 5),
        backend=None, mesh=None, max_shard_n: int = 1024,
        plan: str = "analytic", tune_budget: int = 16,
        params: dict | None = None, seed: int = 0, store=None,
        ckpt_manager=None, ckpt_dir=None, ckpt_every: int = 50,
        log_every: int = 25, log: Callable[[str], None] = print
        ) -> FitResult:
    """Compile one zoo model and train it end to end.

    Args:
      spec: the :class:`~repro.gnn.models.ZooSpec` to train.
      graph: a :class:`~repro.graphs.datasets.GraphData` (labels and
        train_mask default from it) or ``(edges, num_nodes, features)``.
      labels: (N,) int class labels; required for tuple graphs.
      train_mask: (N,) bool loss mask; default: GraphData.train_mask, or
        every node.
      steps: TOTAL optimization steps — resuming from a checkpoint at k
        continues to ``steps``, exactly like an uninterrupted run.
      batch_nodes: 0 trains full-batch; > 0 neighbor-samples mini-batches
        of this many seed nodes with per-layer ``fanout``.
      mesh: a ``(data, model)`` mesh — full-batch data-parallel training
        over the sharded forward (gradient psum via the shard_map
        transpose).
      ckpt_manager / ckpt_dir: resume + periodic checkpointing through
        :class:`~repro.checkpoint.manager.CheckpointManager`.

    Everything else matches :func:`runtime.compile`.
    """
    from repro import runtime

    if hasattr(graph, "profile"):
        if labels is None:
            labels = graph.labels
        if train_mask is None:
            train_mask = graph.train_mask
        features = graph.features
    else:
        edges, num_nodes, features = runtime.api._as_graph(graph)
        if features is None:
            raise ValueError("training needs node features")
    if labels is None:
        raise ValueError("training needs labels (pass labels= or a "
                         "GraphData)")

    exe = runtime.compile(spec, graph, backend=backend, mesh=mesh,
                          max_shard_n=max_shard_n, params=params,
                          plan=plan, tune_budget=tune_budget,
                          seed=seed, store=store)
    opt_cfg = opt or AdamWConfig(
        lr=lr, weight_decay=weight_decay, grad_clip=grad_clip,
        schedule=schedule, warmup_steps=warmup_steps, total_steps=steps)

    sampler = None
    if batch_nodes:
        tm = np.asarray(train_mask, dtype=bool) if train_mask is not None \
            else np.ones(exe.gt.num_nodes, dtype=bool)
        seed_ids = np.flatnonzero(tm)
        edges_np = graph.edges if hasattr(graph, "profile") else \
            np.asarray(graph[0])
        sampler = NeighborSampler(
            edges_np, exe.gt.num_nodes, batch_nodes=batch_nodes,
            fanout=tuple(fanout), seed_ids=seed_ids, seed=seed)

    trainable = TrainableExecutable(
        exe, labels, train_mask=train_mask,
        features=np.asarray(features, dtype=np.float32),
        opt_cfg=opt_cfg, sampler=sampler)

    if ckpt_manager is None and ckpt_dir is not None:
        from repro.checkpoint.manager import CheckpointManager
        ckpt_manager = CheckpointManager(str(ckpt_dir), keep=3)

    history = trainable.run(steps, ckpt_manager=ckpt_manager,
                            ckpt_every=ckpt_every, log_every=log_every,
                            log=log)
    return FitResult(executable=exe, trainable=trainable,
                     params=trainable.params, opt_state=trainable.opt_state,
                     history=history)

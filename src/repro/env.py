"""Centralized XLA/JAX performance-environment knobs (`repro.env`).

The knobs that decide what a wall-clock measurement *means* — float
width, target platform, virtual host-device count — were historically
scattered across CI yaml, test shims and launcher docstrings as raw
``JAX_PLATFORMS`` / ``JAX_ENABLE_X64`` / ``XLA_FLAGS`` strings. This
module is the one place that sets them, and the benchmark/tuning entry
points go through :func:`pin_for_benchmarks` so every recorded number
(BENCH_gnn.json rows, autotuned winners) was taken under a *pinned,
describable* environment.

Ordering matters: ``XLA_FLAGS``/``JAX_PLATFORMS`` only take effect
before jax initializes its backends, so the setters mutate ``os.environ``
and warn (rather than silently no-op) when jax is already live. Always
call these at the top of a ``main()``, before the first repro/jax import
does real work.
"""
from __future__ import annotations

import os
import re
import sys
import warnings

_HOST_DEV_FLAG = "--xla_force_host_platform_device_count"


def _jax_initialized() -> bool:
    """True once jax has picked its backends (env changes stop mattering)."""
    mod = sys.modules.get("jax")
    if mod is None:
        return False
    try:
        # jax.config reads don't initialize backends; the backend registry
        # does, and it exposes whether it already ran
        from jax._src import xla_bridge
        return xla_bridge.backends_are_initialized()
    except Exception:   # noqa: BLE001 — private API moved: assume live
        return True


def _warn_if_late(knob: str) -> None:
    if _jax_initialized():
        warnings.warn(
            f"repro.env: {knob} set after jax initialized its backends — "
            f"it will not take effect in this process", RuntimeWarning,
            stacklevel=3)


def set_platform(platform: str) -> None:
    """Pin the jax platform ("cpu" / "gpu" / "tpu") via ``JAX_PLATFORMS``."""
    _warn_if_late("platform")
    os.environ["JAX_PLATFORMS"] = platform


def set_host_device_count(n: int) -> None:
    """Expose ``n`` virtual host devices (CPU mesh testing), merging into
    any existing ``XLA_FLAGS`` instead of clobbering them."""
    _warn_if_late("host device count")
    flags = os.environ.get("XLA_FLAGS", "")
    flags = re.sub(rf"{_HOST_DEV_FLAG}=\d+\s*", "", flags).strip()
    os.environ["XLA_FLAGS"] = f"{flags} {_HOST_DEV_FLAG}={n}".strip()


def enable_x64(on: bool = True) -> None:
    """Toggle 64-bit jax arrays (works before or after jax import)."""
    os.environ["JAX_ENABLE_X64"] = "1" if on else "0"
    if sys.modules.get("jax") is not None:
        import jax
        jax.config.update("jax_enable_x64", bool(on))


def configure(*, platform: str | None = None, x64: bool | None = None,
              host_devices: int | None = None) -> None:
    """Apply any subset of the knobs in the right order."""
    if host_devices is not None:
        set_host_device_count(host_devices)
    if platform is not None:
        set_platform(platform)
    if x64 is not None:
        enable_x64(x64)


def pin_for_benchmarks(*, platform: str | None = None) -> dict:
    """The pinned measurement environment for benchmarks and tuning runs.

    Pins the platform (default: keep an explicit ``JAX_PLATFORMS`` if the
    caller exported one, else cpu — benchmark numbers must never silently
    move between devices) and 32-bit arrays (the kernels' dtype), then
    returns :func:`describe` for embedding into the result record.
    """
    configure(platform=platform or os.environ.get("JAX_PLATFORMS") or "cpu",
              x64=False)
    return describe()


def describe() -> dict:
    """Snapshot of the execution environment a measurement ran under
    (recorded alongside benchmark rows and autotuned winners)."""
    import jax
    return {
        "jax_version": jax.__version__,
        "jax_platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "x64": bool(jax.config.read("jax_enable_x64")),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
    }

"""Fault-tolerant checkpointing.

Atomic rolling checkpoints: each save writes to a temp directory and
os.rename()s it into place (POSIX-atomic), so a preemption mid-save can
never corrupt the latest checkpoint; a retention policy bounds disk use.
Restore picks the newest complete step.

Layout: <dir>/step_<N>/arrays.npz + meta.json. Arrays are stored flat,
keyed by their pytree path. On a multi-host cluster each host saves its
addressable shards under host_<i>/ and restore re-shards via
jax.make_array_from_single_device_arrays; the single-process path here
stores full arrays (the dry-run container has one process) — the layout
and atomicity story are identical.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in leaves}


def _unflatten(template, arrays: dict):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        arr = arrays[key]
        want = getattr(leaf, "dtype", None)
        a = arr.astype(want) if want is not None and arr.dtype != want else arr
        leaves.append(a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = False          # overlap save with the next train step

    def __post_init__(self):
        self.dir = pathlib.Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, tree, step: int) -> None:
        if self.async_save:
            self.wait()
            host = jax.tree.map(np.asarray, tree)  # snapshot before mutation
            self._thread = threading.Thread(
                target=self._save_sync, args=(host, step), daemon=True)
            self._thread.start()
        else:
            self._save_sync(tree, step)

    def _save_sync(self, tree, step: int) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        arrays = _flatten(tree)
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "num_arrays": len(arrays)}))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self._steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "meta.json").exists():   # complete checkpoints only
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self._steps()
        return max(steps) if steps else None

    def restore(self, template, step: int):
        d = self.dir / f"step_{step:08d}"
        with np.load(d / "arrays.npz") as npz:
            arrays = dict(npz)
        return _unflatten(template, arrays)

    def restore_latest(self, template):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(template, step), step

"""repro.dist — the distribution layer: sharding rules, mesh compat,
HLO collective accounting and multi-device sharded GNN execution.

Three pieces:

  * :mod:`repro.dist.shardings` — logical-axis -> mesh-axis rules with
    divisibility / axis-reuse / missing-axis guards (LM dry-run + train).
  * :mod:`repro.dist.hlo_analysis` — parse compiled HLO text into
    per-collective operand/wire byte counts (the dry-run's traffic model
    and the sharded Executable's comm verification).
  * :mod:`repro.dist.gnn` — ``runtime.compile(spec, graph, mesh=...)``
    support: a :class:`ShardedExecutable` whose forward runs under
    ``shard_map`` (data axis = contiguous dst-shard row groups, model
    axis = feature blocks).

:mod:`repro.dist.compat` papers over jax-version differences in mesh
construction (``AxisType`` only exists on jax >= 0.5).
"""
from repro.dist.compat import abstract_mesh, make_mesh
from repro.dist.hlo_analysis import (CollectiveStats, analyze_collectives,
                                     type_bytes)
from repro.dist.shardings import ShardingRules

__all__ = [
    "ShardingRules", "CollectiveStats", "analyze_collectives", "type_bytes",
    "abstract_mesh", "make_mesh",
]

"""Collective-traffic accounting over compiled HLO text.

``analyze_collectives`` scans ``compiled.as_text()`` for collective
instructions and reports, per op kind:

  * ``operand_bytes`` — the instruction's result-type bytes (for a
    multi-operand fused all-reduce the tuple members are summed),
  * ``wire_bytes``    — estimated bytes on the interconnect per
    instruction, using the standard ring-algorithm costs with ``g`` the
    replica-group size:

        all-reduce          2·(g-1)/g · B      (reduce-scatter + all-gather)
        reduce-scatter        (g-1)/g · B
        all-gather            (g-1)   · B      (B = gathered result; this
                                                equals the total bytes all
                                                g participants put on the
                                                wire)
        all-to-all            (g-1)/g · B
        collective-permute              B

  * ``counts``        — instructions per op kind (``-start`` counted,
    ``-done`` skipped, so async pairs count once).

This is a text-level model — good enough to compare sharding strategies
and to verify the sharded GNN executable's per-layer all-gathers; it does
not claim wire-exact knowledge of XLA's chosen algorithms.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")

# one typed shape, e.g. bf16[8,128] (layout suffix {1,0} never matches)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# "= <type> <opcode>(" — type is a tuple "(...)" or a single token
_INSTR_RE = re.compile(r"=\s*(\([^)]*\)|\S+)\s+([a-z][a-z0-9-]*)\(")
# iota replica groups: [4,16]<=[64] => 4 groups of 16
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")
# explicit replica groups: {{0,1,2,3},{4,5,6,7}} => groups of 4
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples sum their members, scalars
    (``f32[]``) count one element."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(1).split(",")[-1])
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_bytes(op: str, operand_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return operand_bytes * 2.0 * (g - 1) / g
    if op == "reduce-scatter":
        return operand_bytes * (g - 1) / g
    if op == "all-gather":
        return operand_bytes * float(g - 1)
    if op == "all-to-all":
        return operand_bytes * (g - 1) / g
    return float(operand_bytes)  # collective-permute / broadcast


@dataclasses.dataclass
class CollectiveStats:
    """Per-op-kind collective traffic parsed from one HLO module."""

    operand_bytes: dict[str, float]
    wire_bytes: dict[str, float]
    counts: dict[str, int]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def analyze_collectives(hlo_text: str) -> CollectiveStats:
    """Scan HLO text for collective instructions (see module docstring)."""
    operand: dict[str, float] = {}
    wire: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None:
            continue
        type_str, opcode = m.group(1), m.group(2)
        if opcode.endswith("-done"):
            continue  # counted at -start
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base not in COLLECTIVE_OPS:
            continue
        if opcode.endswith("-start") and type_str.startswith("("):
            # async form: the -start result is a tuple holding BOTH the
            # operand and the produced value (plus tiny context tokens on
            # some targets); summing it would double-count. Pick the
            # member matching the sync convention (the collective's
            # result): the largest, except reduce-scatter whose result is
            # the smallest data member.
            members = [type_bytes(m.group(0))
                       for m in _SHAPE_RE.finditer(type_str)]
            b = (min(members) if base == "reduce-scatter"
                 else max(members)) if members else 0
        else:
            b = type_bytes(type_str)
        g = _group_size(line)
        operand[base] = operand.get(base, 0.0) + b
        wire[base] = wire.get(base, 0.0) + _wire_bytes(base, b, g)
        counts[base] = counts.get(base, 0) + 1
    return CollectiveStats(operand_bytes=operand, wire_bytes=wire,
                           counts=counts)

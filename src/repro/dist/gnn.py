"""Multi-device sharded GNN execution (`runtime.compile(..., mesh=...)`).

The paper's 2-D shard grid generalizes directly to a device mesh:

  * the **data** axis owns contiguous dst-shard row groups
    (``graphs/partition.py::partition_graph(..., pad=True)``): each data
    group aggregates its own destination nodes via the shard-grid SpMM
    kernel (``kernels/shard_spmm`` handles the rectangular
    local-rows × full-source-grid blocks);
  * the **model** axis owns feature blocks — the distributed
    generalization of the paper's dimension-blocking: each model device
    aggregates only its ceil(D/n_model) feature slice, and the dense
    stage reduces the partial products with a ``psum`` (row-parallel
    matmul);
  * per layer, each device **all-gathers** the cross-group source rows of
    its feature block over the data axis. That collective is the
    cluster-scale analogue of the paper's Table-I DRAM reads; its
    measured volume (parsed from the compiled HLO by
    ``dist/hlo_analysis.py``) is verified against the
    :class:`~repro.graphs.partition.PartitionPlan` models in
    :meth:`ShardedExecutable.verify_comm`.

Supported zoo architectures: the linear-aggregation family (``gcn``,
``sage_mean``, ``gin``). ``sage_max`` (edge-list max pooling) and ``gat``
(per-head attention grids) need sharded gather/attention plumbing that is
out of scope here and raise ``NotImplementedError`` at compile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist.hlo_analysis import analyze_collectives
from repro.graphs.partition import PartitionPlan, partition_graph
from repro.kernels.ref import _activate
from repro.runtime.executable import Executable
from repro.runtime.forward import layer_activation

SUPPORTED_ARCHS = ("gcn", "sage_mean", "gin")

_F32 = 4


def _pad_last(x, size: int):
    """Zero-pad the trailing (feature) dim up to ``size``."""
    pad = size - x.shape[-1]
    if pad <= 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def _feature_block(x, m, bm: int, n_model: int):
    """This model-device's feature block: pad D to bm·n_model, slice
    [m·bm, (m+1)·bm) off the last dim. ``m`` is a traced axis index."""
    xp = _pad_last(x, bm * n_model)
    return jax.lax.dynamic_slice_in_dim(xp, m * bm, bm, axis=x.ndim - 1)


def _weight_block(w, row_off: int, rows: int, m, bm: int, n_model: int):
    """Rows [row_off, row_off+rows) of ``w``, zero-padded to bm·n_model
    rows, then this model-device's bm-row block — the row-parallel half of
    the partial matmul (zero rows pair with zero-padded features)."""
    wp = jnp.pad(w[row_off:row_off + rows],
                 ((0, bm * n_model - rows), (0, 0)))
    return jax.lax.dynamic_slice_in_dim(wp, m * bm, bm, axis=0)


class ShardedExecutable(Executable):
    """An :class:`~repro.runtime.executable.Executable` whose jitted
    forward runs under ``shard_map`` on a ``(data, model)`` mesh.

    Everything above the forward — the cached full-graph softmax,
    ``predict``/``step`` serving entry points, plan/param serialization —
    is inherited unchanged: the sharded forward returns the same (N, C)
    logits, just computed across the mesh.
    """

    def __init__(self, *, mesh, **kw):
        sizes = dict(mesh.shape)
        if set(sizes) != {"data", "model"}:
            raise ValueError(
                f"sharded execution needs a ('data', 'model') mesh "
                f"(launch.mesh.make_mesh_for builds one); got axes "
                f"{tuple(sizes)}")
        spec, gt = kw["spec"], kw["gt"]
        if spec.arch not in SUPPORTED_ARCHS:
            raise NotImplementedError(
                f"sharded execution supports {SUPPORTED_ARCHS}; "
                f"{spec.arch!r} needs sharded gather/attention kernels")
        self.mesh = mesh
        self.n_data = sizes["data"]
        self.n_model = sizes["model"]
        # pad the shard grid so every data group owns the same number of
        # contiguous dst rows (trailing padded rows hold zero nodes/edges)
        self.rows_per_device = -(-gt.S // self.n_data)
        self.S_pad = self.rows_per_device * self.n_data
        pad = self.S_pad - gt.S
        # pad==0 is the common case (S divisible by n_data); jnp.pad would
        # still copy the dense grid — the single largest tensor here
        self._blocks_padded = gt.blocks if pad == 0 else jnp.pad(
            gt.blocks, ((0, pad), (0, pad), (0, 0), (0, 0)))
        # the comm/balance plan for exactly this (padded, equal) grouping
        self.partition: PartitionPlan = partition_graph(
            gt, self.n_data, pad=True)
        super().__init__(**kw)

    # -- the sharded forward ----------------------------------------------

    def _forward_fn(self):
        spec, be, plans = self.spec, self.backend, self.plan.layers
        gt, mesh = self.gt, self.mesh
        n_model, S_pad, n, N = self.n_model, self.S_pad, gt.n, gt.num_nodes

        def layer_body(i, layer, blocks_loc, h_loc, m):
            """One zoo layer on this device's dst rows + feature block."""
            plan = plans[i]
            act = layer_activation(spec, i)
            d = h_loc.shape[-1]
            bm = -(-d // n_model)
            s_loc = h_loc.shape[0]
            # distributed dimension-blocking: slice this device's feature
            # block FIRST, then all-gather only that block's cross-group
            # source rows over the data axis
            hb_loc = _feature_block(h_loc, m, bm, n_model)
            hb_full = jax.lax.all_gather(hb_loc, "data", axis=0, tiled=True)
            agg = be.graph_aggregate(blocks_loc, hb_full, block_b=plan.B)
            if spec.arch == "gcn":
                wb = _weight_block(layer["w"], 0, d, m, bm, n_model)
                z = be.dense_matmul(agg.reshape(s_loc * n, bm), wb)
            elif spec.arch == "sage_mean":
                # cat([agg, h]) @ w == agg @ w[:d] + h @ w[d:]
                w1 = _weight_block(layer["w"], 0, d, m, bm, n_model)
                w2 = _weight_block(layer["w"], d, d, m, bm, n_model)
                z = (be.dense_matmul(agg.reshape(s_loc * n, bm), w1)
                     + be.dense_matmul(hb_loc.reshape(s_loc * n, bm), w2))
            else:  # gin: two-matmul MLP — psum between them too
                x = (1.0 + layer["eps"]) * hb_loc + agg
                w1 = _weight_block(layer["w1"], 0, d, m, bm, n_model)
                hid = jax.lax.psum(
                    be.dense_matmul(x.reshape(s_loc * n, bm), w1)
                    .astype(jnp.float32), "model") + layer["b1"]
                hid = jax.nn.relu(hid)
                dh = hid.shape[-1]
                bm2 = -(-dh // n_model)
                hid_b = _feature_block(hid, m, bm2, n_model)
                w2 = _weight_block(layer["w2"], 0, dh, m, bm2, n_model)
                z = jax.lax.psum(
                    be.dense_matmul(hid_b, w2).astype(jnp.float32),
                    "model") + layer["b2"]
                return _activate(z, act).astype(h_loc.dtype) \
                    .reshape(s_loc, n, -1)
            # row-parallel partials -> full output columns on every device
            z = jax.lax.psum(z.astype(jnp.float32), "model")
            return _activate(z, act).astype(h_loc.dtype).reshape(s_loc, n, -1)

        def device_fn(p, blocks_loc, h_loc):
            m = jax.lax.axis_index("model")
            for i, layer in enumerate(p["layers"]):
                h_loc = layer_body(i, layer, blocks_loc, h_loc, m)
            return h_loc

        p_specs = jax.tree.map(lambda _: P(), self.params)
        smap = shard_map(device_fn, mesh=mesh,
                         in_specs=(p_specs, P("data", None, None, None),
                                   P("data", None, None)),
                         out_specs=P("data", None, None),
                         check_rep=False)
        blocks_padded = self._blocks_padded

        def fwd(p, h):
            hp = jnp.pad(h, ((0, S_pad - gt.S), (0, 0), (0, 0)))
            out = smap(p, blocks_padded, hp)
            return out.reshape(S_pad * n, -1)[:N]

        return fwd

    # -- communication accounting ------------------------------------------

    def _layer_allgather_bytes(self) -> list[float]:
        """Analytic per-layer all-gather wire bytes of the program above:
        each model device gathers its ceil(d/n_model) feature block of
        every row, so total wire per data group is (n_data-1)·S_pad·n·bm·4
        (the hlo_analysis all-gather convention: gathered result ×
        (g-1))."""
        out = []
        for d, _ in self.spec.layer_dims:
            bm = -(-d // self.n_model)
            out.append(float((self.n_data - 1) * self.S_pad * self.gt.n
                             * bm * _F32))
        return out

    def comm_stats(self) -> dict:
        """Measured (compiled-HLO) vs modeled cross-device traffic.

        ``measured_*`` come from :func:`dist.hlo_analysis.analyze_collectives`
        over the actual compiled module; ``expected_allgather_wire_bytes``
        is the analytic model above; ``plan_*`` are the PartitionPlan's
        graph-level models (per-edge pulls and full-row broadcast)."""
        p_avals = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
            self.params)
        h_aval = jax.ShapeDtypeStruct((self.gt.S, self.gt.n,
                                       self.spec.in_dim), jnp.float32)
        hlo = self._jit_forward.lower(p_avals, h_aval).compile().as_text()
        stats = analyze_collectives(hlo)
        dims = [d for d, _ in self.spec.layer_dims]
        return {
            "n_data": self.n_data,
            "n_model": self.n_model,
            "measured_wire_bytes": dict(stats.wire_bytes),
            "measured_counts": dict(stats.counts),
            "measured_allgather_wire_bytes":
                stats.wire_bytes.get("all-gather", 0.0),
            "expected_allgather_wire_bytes":
                sum(self._layer_allgather_bytes()),
            "plan_transfer_bytes_per_layer": {
                str(i): self.partition.transfer_bytes_per_layer(
                    d, dtype_bytes=_F32)
                for i, d in enumerate(dims)},
            "plan_allgather_bytes_per_layer": {
                str(i): self.partition.allgather_bytes_per_layer(
                    -(-d // self.n_model), self.gt.n, dtype_bytes=_F32)
                for i, d in enumerate(dims)},
            "cross_group_edge_frac": self.partition.cross_group_edge_frac,
        }

    def verify_comm(self, rtol: float = 0.02) -> dict:
        """Assert the measured all-gather volume matches both the analytic
        per-layer model and the PartitionPlan's broadcast model (same
        quantity derived from the plan instead of the program — catching
        drift on either side). The check itself is the comm-contract
        pass (:func:`repro.analyze.hlo_lint.check_sharded_executable`) —
        this wrapper turns its error findings into an AssertionError.
        Returns :meth:`comm_stats`."""
        from repro.analyze.hlo_lint import check_comm_stats
        cs = self.comm_stats()
        findings = check_comm_stats(cs, rtol=rtol)
        errors = [f for f in findings if f.severity == "error"]
        assert not errors, "\n".join(f.render() for f in errors)
        return cs

    # -- introspection -----------------------------------------------------

    def summary(self) -> str:
        head = super().summary()
        per_group = np.asarray(self.partition.comm_matrix.sum(axis=1))
        imb = float(per_group.max() / max(per_group.mean(), 1.0))
        return (head + f"\nmesh: data={self.n_data} model={self.n_model} "
                f"rows/group={self.rows_per_device} (grid padded "
                f"{self.gt.S}->{self.S_pad}) "
                f"cross-group edges "
                f"{self.partition.cross_group_edge_frac:.1%}, "
                f"edge imbalance {imb:.2f}x")

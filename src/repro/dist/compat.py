"""Mesh construction across jax versions.

jax >= 0.5 takes ``AbstractMesh(shape, axes, axis_types=...)`` and
``jax.make_mesh(..., axis_types=...)``; jax 0.4.x has neither ``AxisType``
nor the positional-axes AbstractMesh signature (and the oldest 0.4.x lack
``AbstractMesh``/``jax.make_mesh`` entirely). Everything in this repo that
builds a mesh goes through these two helpers so launch/mesh.py,
tests/test_dist.py and the sharded GNN runtime work on any of them —
importing this module never raises; only ``abstract_mesh`` raises (at
call time) when the running jax truly has no AbstractMesh.
"""
from __future__ import annotations

import math

import jax

try:
    from jax.sharding import AbstractMesh
except ImportError:  # very old jax 0.4.x
    AbstractMesh = None

try:
    from jax.sharding import AxisType
except ImportError:  # jax < 0.5
    AxisType = None


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Concrete device mesh with Auto axis types where they exist."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    import numpy as np
    devices = np.asarray(
        jax.devices()[: math.prod(shape)]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-free AbstractMesh (sharding-rule tests / dry planning)."""
    if AbstractMesh is None:
        raise ImportError(
            "jax.sharding.AbstractMesh unavailable (jax too old); "
            "upgrade jax or use a concrete make_mesh(...)")
    if AxisType is not None:
        return AbstractMesh(shape, axes,
                            axis_types=(AxisType.Auto,) * len(axes))
    return AbstractMesh(tuple(zip(axes, shape)))

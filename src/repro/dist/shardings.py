"""Logical-axis -> mesh-axis sharding rules.

Every parameter / activation / cache tensor in this repo carries a tuple
of *logical* axis names (see ``repro.nn.layers.Axes``). ``ShardingRules``
turns one of those tuples plus a concrete shape into a
``PartitionSpec``, applying three guards:

  * **divisibility** — a dimension is only sharded when its size divides
    the (combined) mesh-axis size; otherwise it falls back to the next
    candidate, then to replicated (odd vocab sizes, 40-head models on a
    16-way axis, batch=1 long-context shapes all stay correct).
  * **axis reuse** — a mesh axis is used at most once per spec; the
    first dimension that claims it wins (``(lru, lru)`` squares shard
    one side only).
  * **missing mesh axes** — rule entries naming axes the mesh doesn't
    have are dropped, so the same table serves single-pod
    ``("data", "model")`` and multi-pod ``("pod", "data", "model")``
    meshes (batch shards over the combined ``("pod", "data")`` axis when
    a pod axis exists, plain ``"data"`` when it doesn't).

A rule value is a tuple of *candidates* tried in order; each candidate
is one mesh-axis name or a tuple of names (sharded over the combined
axis). ``()`` means never shard. ``override()`` returns a new rule set —
the dry-run's ``--override logical=mesh1[+mesh2]`` flag parses into
exactly this format.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Candidate tables: logical axis -> tuple of candidates (see module
# docstring). Anything not listed is replicated.
DEFAULT_RULES: dict[str, tuple] = {
    # activations
    "act_batch": (("pod", "data"),),
    "act_seq": ("model",),
    "act_embed": (),
    # embeddings / output head
    "embed": ("data",),
    "embed_in": (),
    "vocab": ("model",),
    "codebooks": (),
    # attention
    "heads": ("model",),
    "kv_heads": ("model",),
    "kv_heads_n": ("model",),
    "head_dim": (),
    "cache_seq": (),
    # MLP / MoE
    "mlp": ("model",),
    "experts": (),
    "moe_cap": (),
    "ef": ("model",),
    # recurrent / SSM mixers
    "lru": ("model",),
    "lru_gate": ("model",),
    "conv_w": (),
    "ssm_in": ("model",),
    "ssm_inner": ("model",),
    "ssm_conv": ("model",),
    "ssm_heads": ("model",),
    "ssm_p": (),
    "ssm_state": (),
    # misc input axes / scan-stacked layer axis
    "mrope3": (),
    "layers": (),
}


def _normalize_rule(value) -> tuple:
    """Accept a bare axis name, a candidate tuple, or () (= unsharded)."""
    if isinstance(value, str):
        return (value,)
    return tuple(value)


class ShardingRules:
    """Sharding-rule table bound to one mesh (concrete or abstract)."""

    def __init__(self, mesh, rules: dict[str, tuple] | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES) if rules is None else rules
        # works for Mesh and AbstractMesh on every supported jax version
        self._axis_sizes = dict(mesh.shape)

    def override(self, **overrides) -> "ShardingRules":
        """New rules with the given logical axes remapped (``()`` ->
        replicated, ``"model"`` / ``("pod", "data")`` / candidate tuples
        as in the table)."""
        new = dict(self.rules)
        for name, value in overrides.items():
            new[name] = _normalize_rule(value)
        return ShardingRules(self.mesh, new)

    # -- spec construction -------------------------------------------------

    def spec(self, shape: tuple[int, ...], axes) -> P:
        """PartitionSpec for one tensor: shape + logical axis names."""
        names = tuple(axes)
        if len(names) != len(shape):
            raise ValueError(f"rank mismatch: shape {shape} vs axes {names}")
        entries: list = []
        used: set[str] = set()
        for dim, name in zip(shape, names):
            entry = None
            for cand in map(_normalize_rule, self.rules.get(name, ())):
                mesh_axes = tuple(a for a in cand if a in self._axis_sizes)
                if not mesh_axes or any(a in used for a in mesh_axes):
                    continue
                total = math.prod(self._axis_sizes[a] for a in mesh_axes)
                if total <= 1 or dim % total != 0:
                    continue
                entry = mesh_axes[0] if len(mesh_axes) == 1 else mesh_axes
                used.update(mesh_axes)
                break
            entries.append(entry)
        return P(*entries)

    def sharding(self, shape: tuple[int, ...], axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))

    # -- pytree variants ---------------------------------------------------

    def tree_specs(self, tree, axes_tree):
        """Map a pytree of avals/arrays + a matching logical-axes tree
        (``Axes`` leaves) to a pytree of PartitionSpecs."""
        return jax.tree.map(lambda x, ax: self.spec(x.shape, ax),
                            tree, axes_tree)

    def tree_shardings(self, tree, axes_tree):
        return jax.tree.map(lambda x, ax: self.sharding(x.shape, ax),
                            tree, axes_tree)

    # -- activation constraint (the Constrain protocol of models/lm.py) ---

    def constrain(self, x, axes):
        """``with_sharding_constraint`` for one activation (used inside
        jit; a no-op spec is still a valid constraint)."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(x.shape, axes))

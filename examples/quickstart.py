"""Quickstart: train the paper's GCN on (synthetic) Cora with the
GNNerator engines — dimension-blocked shard aggregation on the Graph
Engine, fused feature extraction on the Dense Engine.

    PYTHONPATH=src python examples/quickstart.py [--epochs 30]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.core.models import (build_graph_tensors, init_gnn, make_forward,
                               paper_spec)
from repro.graphs.datasets import make_dataset
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora",
                    choices=["cora", "citeseer", "pubmed"])
    ap.add_argument("--network", default="gcn",
                    choices=["gcn", "graphsage", "graphsage_pool"])
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--shard-n", type=int, default=512,
                    help="nodes per shard (the paper's n)")
    args = ap.parse_args()

    ds = make_dataset(args.dataset)
    print(f"{ds.profile.name}: {ds.profile.num_nodes} nodes, "
          f"{ds.edges.shape[0]} edges, {ds.profile.feature_dim} features "
          f"({ds.size_mb:.1f} MB)")
    gt = build_graph_tensors(ds.edges, ds.profile.num_nodes, args.shard_n,
                             args.network)
    print(f"shard grid: {gt.S}x{gt.S} (n={gt.n})")

    spec = paper_spec(args.network, ds.profile.feature_dim,
                      ds.profile.num_classes)
    params = init_gnn(jax.random.key(0), spec)
    fwd = make_forward(spec)
    feats = gt.group(jnp.asarray(ds.features))
    labels = jnp.asarray(ds.labels)
    mask = jnp.asarray(ds.train_mask)

    def loss_fn(p):
        logits = fwd(p, gt, feats)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        return jnp.sum(nll * mask) / jnp.sum(mask), logits

    opt_cfg = AdamWConfig(lr=5e-3, weight_decay=0.0, schedule="constant",
                          warmup_steps=0, grad_clip=0)
    opt = adamw_init(params)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    for epoch in range(args.epochs):
        t0 = time.time()
        (loss, logits), grads = grad_fn(params)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        acc = float(jnp.mean((jnp.argmax(logits, -1) == labels)[~ds.train_mask]))
        print(f"epoch {epoch:3d} loss {float(loss):.4f} "
              f"test-acc {acc:.3f} ({time.time() - t0:.2f}s)")
    print("done.")
    return 0


if __name__ == "__main__":
    sys.exit(main())

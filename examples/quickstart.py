"""Quickstart: train a zoo GNN on (synthetic) Cora through the runtime.

One ``runtime.fit()`` call compiles the model (the planner picks feature
block size B, shard grid, traversal order, fused vs two-stage per layer),
runs the jitted AdamW train step — full-batch by default, neighbor-sampled
mini-batches with ``--batch-nodes`` — and hands back the trained,
servable Executable.

    PYTHONPATH=src python examples/quickstart.py [--epochs 30] \
        [--backend reference] [--batch-nodes 256]
"""
import argparse
import sys
import time

import jax.numpy as jnp

from repro import runtime
from repro.gnn.models import ZooSpec
from repro.graphs.datasets import make_dataset

# paper Table-III names -> zoo architectures
NETWORKS = {"gcn": "gcn", "graphsage": "sage_mean",
            "graphsage_pool": "sage_max"}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora",
                    choices=["cora", "citeseer", "pubmed"])
    ap.add_argument("--network", default="gcn", choices=sorted(NETWORKS))
    ap.add_argument("--epochs", type=int, default=30,
                    help="full-batch steps (or mini-batch steps with "
                         "--batch-nodes)")
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--shard-n", type=int, default=512,
                    help="planner cap on nodes per shard (the paper's n)")
    ap.add_argument("--batch-nodes", type=int, default=0,
                    help="0 = full-batch; >0 neighbor-samples this many "
                         "seed nodes per step")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "jax", "reference", "ref"],
                    help="kernel backend (default: REPRO_KERNEL_BACKEND "
                         "env, else pallas — interpret mode on CPU)")
    args = ap.parse_args()

    ds = make_dataset(args.dataset)
    print(f"{ds.profile.name}: {ds.profile.num_nodes} nodes, "
          f"{ds.edges.shape[0]} edges, {ds.profile.feature_dim} features "
          f"({ds.size_mb:.1f} MB)")

    spec = ZooSpec(NETWORKS[args.network], ds.profile.feature_dim,
                   args.hidden, ds.profile.num_classes, num_layers=2)
    t0 = time.time()
    result = runtime.fit(spec, ds, steps=args.epochs, lr=5e-3,
                         backend=args.backend, max_shard_n=args.shard_n,
                         batch_nodes=args.batch_nodes, fanout=(10, 5),
                         log_every=max(1, args.epochs // 10))
    exe = result.executable               # trained weights already swapped in
    print(exe.summary())

    labels = jnp.asarray(ds.labels)
    logits = exe.forward()
    test_acc = float(jnp.mean(
        (jnp.argmax(logits, -1) == labels)[~ds.train_mask]))
    print(f"trained in {time.time() - t0:.1f}s: "
          f"train-acc {result.train_accuracy():.3f} test-acc {test_acc:.3f}")
    classes, probs = exe.predict([0, 1, 2])
    print(f"predict([0,1,2]) -> classes {classes.tolist()} "
          f"(p={[round(float(p), 3) for p in probs]})")
    print("done.")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Quickstart: train a zoo GNN on (synthetic) Cora through the runtime.

One ``runtime.compile()`` call plans the layer execution (feature-block
size B, shard grid, traversal order, fused vs two-stage), shards the graph
for the architecture's normalization signature, and jits the forward on
the chosen kernel backend; ``Executable.forward(params)`` is
differentiable, so the same entry point drives training.

    PYTHONPATH=src python examples/quickstart.py [--epochs 30] \
        [--backend reference]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro import runtime
from repro.gnn.models import ZooSpec
from repro.graphs.datasets import make_dataset
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

# paper Table-III names -> zoo architectures
NETWORKS = {"gcn": "gcn", "graphsage": "sage_mean",
            "graphsage_pool": "sage_max"}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora",
                    choices=["cora", "citeseer", "pubmed"])
    ap.add_argument("--network", default="gcn", choices=sorted(NETWORKS))
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--shard-n", type=int, default=512,
                    help="planner cap on nodes per shard (the paper's n)")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "jax", "reference", "ref"],
                    help="kernel backend (default: REPRO_KERNEL_BACKEND "
                         "env, else pallas — interpret mode on CPU)")
    args = ap.parse_args()

    ds = make_dataset(args.dataset)
    print(f"{ds.profile.name}: {ds.profile.num_nodes} nodes, "
          f"{ds.edges.shape[0]} edges, {ds.profile.feature_dim} features "
          f"({ds.size_mb:.1f} MB)")

    spec = ZooSpec(NETWORKS[args.network], ds.profile.feature_dim,
                   args.hidden, ds.profile.num_classes, num_layers=2)
    exe = runtime.compile(spec, ds, backend=args.backend,
                          max_shard_n=args.shard_n)
    print(exe.summary())

    params = exe.params
    labels = jnp.asarray(ds.labels)
    mask = jnp.asarray(ds.train_mask)

    def loss_fn(p):
        logits = exe.forward(p)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        return jnp.sum(nll * mask) / jnp.sum(mask), logits

    opt_cfg = AdamWConfig(lr=5e-3, weight_decay=0.0, schedule="constant",
                          warmup_steps=0, grad_clip=0)
    opt = adamw_init(params)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    for epoch in range(args.epochs):
        t0 = time.time()
        (loss, logits), grads = grad_fn(params)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        acc = float(jnp.mean((jnp.argmax(logits, -1) == labels)[~ds.train_mask]))
        print(f"epoch {epoch:3d} loss {float(loss):.4f} "
              f"test-acc {acc:.3f} ({time.time() - t0:.2f}s)")
    exe.set_params(params)   # trained weights now serve from the Executable
    print("done.")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Dataflow explorer: the paper's Algorithm-1 schedule, Table-I costs and
the platform model, interactively — ending with what ``runtime.compile``
actually picks for a zoo model on this graph.

    PYTHONPATH=src python examples/dataflow_explorer.py --dataset pubmed \
        --block 64 --budget-mb 24
"""
import argparse
import sys

from repro import runtime
from repro.core.dataflow import (Dataflow, best_order, blocked_vs_conventional,
                                 simulate_traffic, table1_costs)
from repro.core.perf_model import (GNNERATOR, GNNERATOR_NOBLOCK, GPU_2080TI,
                                   HYGCN, model_time)
from repro.core.sharding import max_shard_nodes_for_budget, shard_graph
from repro.gnn.models import ZooSpec
from repro.graphs.datasets import make_dataset


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="pubmed")
    ap.add_argument("--block", type=int, default=64)
    ap.add_argument("--budget-mb", type=float, default=24.0)
    args = ap.parse_args()

    ds = make_dataset(args.dataset)
    d = ds.profile.feature_dim
    budget = int(args.budget_mb * 2 ** 20)

    print(f"=== {ds.profile.name}: N={ds.profile.num_nodes} "
          f"E={ds.edges.shape[0]} D={d} ===\n")

    cmp = blocked_vs_conventional(num_nodes=ds.profile.num_nodes, D=d,
                                  B=args.block, onchip_bytes=budget)
    print(f"conventional dataflow: n={cmp['n_conventional']} nodes/shard "
          f"-> S={cmp['S_conventional']}")
    print(f"dimension-blocked (B={args.block}): n={cmp['n_blocked']} "
          f"-> S={cmp['S_blocked']}")
    print(f"off-chip traffic ratio (conv/blocked): "
          f"{cmp['traffic_ratio']:.2f}x\n")

    n = max_shard_nodes_for_budget(budget, args.block)
    sg = shard_graph(ds.edges, ds.profile.num_nodes, n)
    print(f"actual sharding: {sg.S}x{sg.S} grid, occupied-block density "
          f"{sg.density:.4f}")
    print(f"best traversal order (Table I): {best_order(sg.S)}")
    for order in ("dst_stationary", "src_stationary"):
        tr = simulate_traffic(Dataflow(S=sg.S, D=d, B=args.block, order=order),
                              nodes_per_shard=n, edges_per_shard=sg.occupancy)
        print(f"  {order:16s}: {tr.offchip_bytes / 2**20:8.1f} MiB off-chip, "
              f"{tr.onchip_edge_reads / 1e6:6.2f}M edge walks")
    print(f"  Table-I (S={sg.S}): {table1_costs(sg.S)}\n")

    print("platform model (GCN, end-to-end):")
    for p in (GPU_2080TI, HYGCN, GNNERATOR_NOBLOCK, GNNERATOR):
        t = model_time(p, "gcn", args.dataset, block_b=args.block)
        print(f"  {p.name:18s}: {t * 1e3:8.3f} ms")

    # what the runtime's compile step actually schedules for this graph
    # (quarter-scale copy: compiling densifies shard blocks on device, and
    # the explorer only needs to show the plan, not pay full-graph memory)
    demo = make_dataset(args.dataset, scale=0.25)
    spec = ZooSpec("gcn", demo.profile.feature_dim, 16,
                   demo.profile.num_classes, num_layers=2)
    exe = runtime.compile(spec, demo, backend="reference")
    print("\nruntime.compile plan (2-layer GCN, scale=0.25):")
    print(exe.summary())
    return 0


if __name__ == "__main__":
    sys.exit(main())

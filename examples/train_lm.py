"""End-to-end LM training driver: trains a reduced config of any assigned
architecture for a few hundred steps on CPU with the full production
substrate — AdamW + schedule, remat, atomic rolling checkpoints, resume
after preemption, optional int8 gradient compression.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2.5-3b \
        --steps 300 --d-model 256 --layers 4

The data is a synthetic structured stream (a 2nd-order Markov chain), so
the loss has real signal to descend — final loss far below the uniform
log(V) floor demonstrates the whole stack learns.
"""
import argparse
import dataclasses
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke
from repro.models import lm
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import TrainLoop, make_train_step


def markov_batches(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic-by-step synthetic data with learnable structure
    (1st-order Markov chain + 10% noise: optimal loss ≈ 0.1·log V)."""
    rng = np.random.default_rng(seed)
    table = rng.integers(0, vocab, (vocab,)).astype(np.int32)

    def at(step: int):
        r = np.random.default_rng(seed * 7919 + step)
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = r.integers(0, vocab, batch)
        noise = r.random((batch, seq + 1)) < 0.1
        for t in range(1, seq + 1):
            toks[:, t] = table[toks[:, t - 1]]
            flip = noise[:, t]
            toks[flip, t] = r.integers(0, vocab, int(flip.sum()))
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    return at


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="wsd", choices=["cosine", "wsd",
                                                          "constant"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    # scale the smoke config up to the requested size
    period = lm.pattern_period(cfg)
    layers = max(period, (args.layers // period) * period)
    pat = tuple(cfg.pattern[i % period] for i in range(layers)) \
        if cfg.block_pattern else ()
    cfg = dataclasses.replace(cfg, n_layers=layers, block_pattern=pat,
                              d_model=args.d_model,
                              d_ff=args.d_model * 3)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"params={cfg.num_params()/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr=args.lr, schedule=args.schedule,
                          warmup_steps=max(10, args.steps // 20),
                          total_steps=args.steps)
    params = lm.init_params(cfg, jax.random.key(0))
    opt_state = adamw_init(params)
    if args.compress_grads:
        opt_state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    data = markov_batches(cfg.vocab_size, args.batch, args.seq)
    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)
    loop = TrainLoop(cfg, opt_cfg, data, ckpt_manager=mgr,
                     ckpt_every=args.ckpt_every, log_every=10)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False,
                                      compress_grads=args.compress_grads),
                      donate_argnums=(0, 1))
    params, opt_state, losses = loop.run(params, opt_state, args.steps,
                                         train_step=step_fn)
    first, last = losses[0][1], losses[-1][1]
    uniform = float(np.log(cfg.vocab_size))
    print(f"\nloss: {first:.3f} -> {last:.3f} (uniform floor {uniform:.3f})")
    assert last < first, "training did not reduce loss"
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Batched serving example: prefill + incremental decode with per-layer
caches (KV ring buffers / recurrent states), greedy and sampled requests,
across attention, hybrid (RG-LRU) and SSM (Mamba2) architectures.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""
import argparse
import sys
import time

import numpy as np
import jax

from repro.configs.registry import ARCHS, get_smoke
from repro.models import lm
from repro.serving.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    if cfg.input_mode != "tokens":
        print(f"{args.arch} takes frontend embeddings; serving demo uses "
              f"token archs — switching to qwen3-8b")
        cfg = get_smoke("qwen3-8b")
    params = lm.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens + 1)

    rng = np.random.default_rng(0)
    shape = (args.prompt_len, cfg.n_codebooks) if cfg.n_codebooks > 1 \
        else (args.prompt_len,)
    reqs = [Request(rng.integers(0, cfg.vocab_size, shape).astype(np.int32),
                    max_new_tokens=args.new_tokens,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(args.batch)]
    t0 = time.time()
    outs = eng.generate(reqs, seed=1)
    dt = time.time() - t0
    total = sum(o.shape[0] for o in outs)
    print(f"arch={cfg.name}: served {len(reqs)} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s on CPU)")
    for i, o in enumerate(outs):
        head = o[:8].tolist() if o.ndim == 1 else o[:4].tolist()
        print(f"  req{i} (T={reqs[i].temperature}): {head} ...")
    return 0


if __name__ == "__main__":
    sys.exit(main())

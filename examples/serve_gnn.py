"""Serve GNN node-classification requests end-to-end.

Runs a 2-layer GCN and a 2-layer GAT from the repro.gnn model zoo through
serving/gnn_engine.py on the synthetic Cora profile. Each (model, graph)
pair is compiled once via ``repro.runtime`` — the planner picks
(S, B, order, fused) per layer from the Table-I cost model, the runtime
GraphStore shards + caches the graph once per normalization signature —
and batches of node-id requests come back as class predictions with
cache-hit stats.

    PYTHONPATH=src python examples/serve_gnn.py [--scale 1.0] [--requests 32]

(The default Pallas kernels run in interpret mode on CPU, which is slow at
full Cora scale — pass --backend reference or a smaller --scale for a
quick run.)
"""
import argparse
import os
import sys
import time

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora",
                    choices=["cora", "citeseer", "pubmed"])
    ap.add_argument("--scale", type=float, default=0.25,
                    help="graph scale factor (1.0 = full Table-II profile)")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "jax", "reference", "ref"],
                    help="kernel backend (default: REPRO_KERNEL_BACKEND "
                         "env var, else reference — fast pure-jnp on CPU)")
    ap.add_argument("--requests", "--num-requests", dest="requests",
                    type=int, default=32)
    ap.add_argument("--hidden", type=int, default=16)
    args = ap.parse_args()
    backend = (args.backend or os.environ.get("REPRO_KERNEL_BACKEND")
               or "reference")

    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.serving.gnn_engine import GNNServeEngine, NodeRequest

    ds = make_dataset(args.dataset, seed=0, scale=args.scale)
    prof = ds.profile
    print(f"{prof.name}: {prof.num_nodes} nodes, {ds.edges.shape[0]} edges, "
          f"{prof.feature_dim} features, {prof.num_classes} classes")

    engine = GNNServeEngine(max_shard_n=512, backend=backend)
    engine.register_graph(args.dataset, ds)
    engine.register_model("gcn-2l", ZooSpec("gcn", prof.feature_dim,
                                            args.hidden, prof.num_classes,
                                            num_layers=2))
    engine.register_model("gat-2l", ZooSpec("gat", prof.feature_dim,
                                            args.hidden, prof.num_classes,
                                            num_layers=2, heads=2))

    # show what each (model, graph) pair compiled to
    for name in ("gcn-2l", "gat-2l"):
        print("\n" + engine.executable(name, args.dataset).summary())

    rng = np.random.default_rng(7)
    for i in range(args.requests):
        ids = rng.integers(0, prof.num_nodes,
                           size=int(rng.integers(1, 9)))
        engine.submit(NodeRequest(args.dataset, ids,
                                  model="gcn-2l" if i % 2 else "gat-2l"))

    t0 = time.time()
    preds = engine.flush()
    dt = time.time() - t0

    print(f"\nserved {len(preds)} requests in {dt:.2f}s "
          f"({len(preds) / dt:.1f} req/s); per-request predictions:")
    for p in preds[:6]:
        print(f"  {p.model}: nodes {p.node_ids.tolist()} -> "
              f"classes {p.classes.tolist()}")
    if len(preds) > 6:
        print(f"  ... ({len(preds) - 6} more)")
    print("\n" + engine.cache_report())
    return 0


if __name__ == "__main__":
    sys.exit(main())

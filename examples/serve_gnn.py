"""Serve GNN node-classification requests through the async Server API.

Runs a 2-layer GCN and a 2-layer GAT from the repro.gnn model zoo behind
the continuous-batching :class:`repro.serving.Server`. Each (model, graph)
pair is compiled once via ``repro.runtime`` — the planner picks
(S, B, order, fused) per layer from the Table-I cost model, the runtime
GraphStore shards + caches the graph once per normalization signature —
and node-id requests go in as tickets (with priorities and deadlines),
micro-batch per (model, graph) stream, and come back as typed outcomes
with per-request queue/engine latency.

    PYTHONPATH=src python examples/serve_gnn.py [--scale 1.0] [--requests 32]

(The default Pallas kernels run in interpret mode on CPU, which is slow at
full Cora scale — pass --backend reference or a smaller --scale for a
quick run.)
"""
import argparse
import os
import sys
import time

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora",
                    choices=["cora", "citeseer", "pubmed"])
    ap.add_argument("--scale", type=float, default=0.25,
                    help="graph scale factor (1.0 = full Table-II profile)")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "jax", "reference", "ref"],
                    help="kernel backend (default: REPRO_KERNEL_BACKEND "
                         "env var, else reference — fast pure-jnp on CPU)")
    ap.add_argument("--requests", "--num-requests", dest="requests",
                    type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="scheduler max micro-batch size")
    ap.add_argument("--hidden", type=int, default=16)
    args = ap.parse_args()
    backend = (args.backend or os.environ.get("REPRO_KERNEL_BACKEND")
               or "reference")

    from repro.gnn.models import ZooSpec
    from repro.graphs.datasets import make_dataset
    from repro.serving import (Completed, NodeRequest, SchedulerConfig,
                               Server)
    from repro.serving.gnn_engine import GNNServeEngine

    ds = make_dataset(args.dataset, seed=0, scale=args.scale)
    prof = ds.profile
    print(f"{prof.name}: {prof.num_nodes} nodes, {ds.edges.shape[0]} edges, "
          f"{prof.feature_dim} features, {prof.num_classes} classes")

    engine = GNNServeEngine(max_shard_n=512, backend=backend)
    engine.register_graph(args.dataset, ds)
    engine.register_model("gcn-2l", ZooSpec("gcn", prof.feature_dim,
                                            args.hidden, prof.num_classes,
                                            num_layers=2))
    engine.register_model("gat-2l", ZooSpec("gat", prof.feature_dim,
                                            args.hidden, prof.num_classes,
                                            num_layers=2, heads=2))

    # show what each (model, graph) pair compiled to
    for name in ("gcn-2l", "gat-2l"):
        print("\n" + engine.executable(name, args.dataset).summary())

    server = Server(engine, SchedulerConfig(max_batch_size=args.batch_size))

    rng = np.random.default_rng(7)
    t0 = time.time()
    tickets = []
    for i in range(args.requests):
        ids = rng.integers(0, prof.num_nodes,
                           size=int(rng.integers(1, 9)))
        tickets.append(server.submit(
            NodeRequest(args.dataset, ids,
                        model="gcn-2l" if i % 2 else "gat-2l"),
            priority=1 if i % 8 == 0 else 0))
    # submit() is non-blocking: tickets are pending until the scheduler runs
    assert tickets[0].poll() is None
    server.drain()
    dt = time.time() - t0

    outcomes = [t.result() for t in tickets]
    done = [o for o in outcomes if isinstance(o, Completed)]
    print(f"\nserved {len(done)} requests in {dt:.2f}s "
          f"({len(done) / dt:.1f} req/s); per-request predictions:")
    for o in done[:6]:
        p = o.value
        print(f"  {p.model}: nodes {p.node_ids.tolist()} -> "
              f"classes {p.classes.tolist()} "
              f"(queue {o.queue_ms:.2f} ms, engine {o.engine_ms:.2f} ms)")
    if len(done) > 6:
        print(f"  ... ({len(done) - 6} more)")
    print("\n" + engine.cache_report())
    print(server.report())
    return 0


if __name__ == "__main__":
    sys.exit(main())
